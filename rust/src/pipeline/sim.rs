//! Event-driven virtual-time simulator of a synchronous training pipeline.
//!
//! Reproduces the timing model behind the paper's Tables 2/3/5 and
//! Figures 4/5: per-stage compute times, per-boundary FIFO links with
//! bandwidth/latency, comm/comp overlap (sends are asynchronous; a stage
//! only blocks on *receiving* its input), and a configurable microbatch
//! schedule. Deterministic and fast (millions of ops/s), so the bench
//! harnesses can sweep every (bandwidth x scheme x bits) cell.
//!
//! The op-retirement engine itself lives in [`super::step`] and is shared
//! with the numeric executor (`pipeline::exec`); this module only supplies
//! the timing-only driver and the table-shaped result type.

use super::schedule::{Op, Schedule};
use super::step::{run_step, StepConfig, StepDriver};
use crate::util::error::Result;

/// Per-microbatch compute times of one stage (seconds).
#[derive(Clone, Copy, Debug)]
pub struct StageTimes {
    pub fwd_s: f64,
    pub bwd_s: f64,
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub n_stages: usize,
    pub n_micro: usize,
    pub stage_times: Vec<StageTimes>,
    /// Forward-message wire bytes per microbatch (may differ in AQ-SGD's
    /// first epoch where messages are full precision).
    pub fw_bytes: Vec<u64>,
    /// Backward-message wire bytes (uniform across microbatches).
    pub bw_bytes: u64,
    pub bandwidth_bps: f64,
    /// Per-boundary bandwidth override (length n_stages-1) for the
    /// heterogeneous / decentralized setting of paper App. E; falls back
    /// to `bandwidth_bps` when None.
    pub link_bandwidths: Option<Vec<f64>>,
    pub latency_s: f64,
    pub schedule: Schedule,
    /// Optimizer / codec overhead added once per step (seconds).
    pub step_overhead_s: f64,
}

impl SimConfig {
    /// Uniform-stage convenience constructor.
    pub fn uniform(
        n_stages: usize,
        n_micro: usize,
        fwd_s: f64,
        bwd_s: f64,
        fw_bytes: u64,
        bw_bytes: u64,
        bandwidth_bps: f64,
    ) -> Self {
        SimConfig {
            n_stages,
            n_micro,
            stage_times: vec![StageTimes { fwd_s, bwd_s }; n_stages],
            fw_bytes: vec![fw_bytes; n_micro],
            bw_bytes,
            bandwidth_bps,
            link_bandwidths: None,
            latency_s: 0.0,
            schedule: Schedule::GPipe,
            step_overhead_s: 0.0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct SimResult {
    /// End-to-end time of one optimizer step (seconds).
    pub step_time_s: f64,
    /// Per-stage busy (compute) time.
    pub stage_busy_s: Vec<f64>,
    /// Total bytes crossing each forward link.
    pub fw_link_bytes: Vec<u64>,
    pub bw_link_bytes: Vec<u64>,
    /// Average per-message transmission times (Table 3's comm columns).
    pub fw_msg_tx_s: f64,
    pub bw_msg_tx_s: f64,
    /// Mean stall time per stage (waiting on the network).
    pub stall_s: Vec<f64>,
}

impl SimResult {
    /// Sequences per second given the micro-batch size.
    pub fn throughput(&self, n_micro: usize, micro_batch: usize) -> f64 {
        (n_micro * micro_batch) as f64 / self.step_time_s
    }
}

pub struct PipelineSim;

/// Timing-only [`StepDriver`]: per-stage compute times and fixed message
/// sizes from a [`SimConfig`], no numerics. Infallible.
struct TimingDriver<'a> {
    cfg: &'a SimConfig,
}

impl StepDriver for TimingDriver<'_> {
    fn exec(&mut self, stage: usize, op: Op) -> Result<(f64, Option<u64>)> {
        let k = self.cfg.n_stages;
        Ok(match op {
            Op::Fwd(mb) => (
                self.cfg.stage_times[stage].fwd_s,
                (stage + 1 < k).then(|| self.cfg.fw_bytes[mb]),
            ),
            Op::Bwd(_) => {
                (self.cfg.stage_times[stage].bwd_s, (stage > 0).then_some(self.cfg.bw_bytes))
            }
        })
    }
}

impl PipelineSim {
    pub fn run(cfg: &SimConfig) -> SimResult {
        let k = cfg.n_stages;
        let m = cfg.n_micro;
        assert_eq!(cfg.stage_times.len(), k);
        assert_eq!(cfg.fw_bytes.len(), m);

        let step_cfg = StepConfig {
            n_stages: k,
            n_micro: m,
            bandwidth_bps: cfg.bandwidth_bps,
            link_bandwidths: cfg.link_bandwidths.clone(),
            latency_s: cfg.latency_s,
            schedule: cfg.schedule,
        };
        let timing = run_step(&step_cfg, &mut TimingDriver { cfg })
            .expect("timing driver is infallible");

        let step_time_s = timing.step_time_s + cfg.step_overhead_s;
        let fw_tx = if k > 1 {
            cfg.fw_bytes.iter().map(|&b| b as f64 * 8.0 / cfg.bandwidth_bps).sum::<f64>()
                / m as f64
        } else {
            0.0
        };
        let bw_tx =
            if k > 1 { cfg.bw_bytes as f64 * 8.0 / cfg.bandwidth_bps } else { 0.0 };

        SimResult {
            step_time_s,
            stage_busy_s: timing.stage_busy_s,
            fw_link_bytes: timing.fw_link_bytes,
            bw_link_bytes: timing.bw_link_bytes,
            fw_msg_tx_s: fw_tx,
            bw_msg_tx_s: bw_tx,
            stall_s: timing.stall_s,
        }
    }

    /// Ring all-reduce time for the data-parallel gradient sync
    /// (2 (r-1)/r * bytes across the slowest link), used by the Fig. 5
    /// end-to-end compression harness.
    pub fn allreduce_time(bytes: u64, dp_degree: usize, bandwidth_bps: f64, latency_s: f64) -> f64 {
        if dp_degree <= 1 {
            return 0.0;
        }
        let vol = 2.0 * (dp_degree as f64 - 1.0) / dp_degree as f64 * bytes as f64;
        vol * 8.0 / bandwidth_bps + 2.0 * (dp_degree as f64 - 1.0) * latency_s
    }

    /// All-gather ring time for the CommPlane's framed gradient exchange
    /// (`net::plane::DpRing`): `degree - 1` serialized hop rounds, each
    /// gated by the largest frame forwarded that round. `max_frame_bytes`
    /// is measured off the real serialized frames, never re-derived.
    pub fn ring_allgather_time(
        max_frame_bytes: u64,
        degree: usize,
        bandwidth_bps: f64,
        latency_s: f64,
    ) -> f64 {
        if degree <= 1 {
            return 0.0;
        }
        (degree - 1) as f64 * (max_frame_bytes as f64 * 8.0 / bandwidth_bps + latency_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_stage_is_pure_compute() {
        let cfg = SimConfig::uniform(1, 4, 0.01, 0.02, 0, 0, 1e9);
        let r = PipelineSim::run(&cfg);
        assert!((r.step_time_s - 4.0 * 0.03).abs() < 1e-9);
    }

    #[test]
    fn infinite_bandwidth_matches_gpipe_formula() {
        // with zero comm, GPipe step = (M + K - 1) * (f + b) for uniform
        // stages ... actually (M + K - 1)*f + (M + K - 1)*b for f == b
        let (k, m, f, b) = (4, 8, 0.01, 0.02);
        let cfg = SimConfig::uniform(k, m, f, b, 0, 0, 1e12);
        let r = PipelineSim::run(&cfg);
        let ideal = (m + k - 1) as f64 * (f + b);
        assert!((r.step_time_s - ideal).abs() < 1e-6, "{} vs {ideal}", r.step_time_s);
    }

    #[test]
    fn slow_network_dominates() {
        // 100 Mbps, 4 MB messages: 320 ms per hop >> 10 ms compute
        let cfg = SimConfig::uniform(2, 4, 0.01, 0.02, 4_000_000, 4_000_000, 100e6);
        let r = PipelineSim::run(&cfg);
        // at least the serialized fw+bw transfers of all microbatches
        assert!(r.step_time_s > 8.0 * 0.32);
        // and a fat pipe removes that
        let fast = SimConfig { bandwidth_bps: 100e9, ..cfg.clone() };
        let rf = PipelineSim::run(&fast);
        assert!(rf.step_time_s < r.step_time_s / 4.0);
    }

    #[test]
    fn compression_speeds_up_slow_network() {
        // the Table 2 effect in the paper's own regime (GPT2-1.5B, 8
        // stages, 6.4 MB boundary messages, fw4/bw8): large speedup at
        // 100 Mbps, none at 10 Gbps
        let base = SimConfig::uniform(8, 32, 0.045, 0.135, 6_400_000, 6_400_000, 100e6);
        let comp = SimConfig {
            fw_bytes: vec![800_000; 32],
            bw_bytes: 1_600_000,
            ..base.clone()
        };
        let t_fp32 = PipelineSim::run(&base).step_time_s;
        let t_q = PipelineSim::run(&comp).step_time_s;
        assert!(t_fp32 / t_q > 2.0, "speedup {}", t_fp32 / t_q);

        let fast_fp32 =
            PipelineSim::run(&SimConfig { bandwidth_bps: 10e9, ..base }).step_time_s;
        let fast_q =
            PipelineSim::run(&SimConfig { bandwidth_bps: 10e9, ..comp }).step_time_s;
        assert!((fast_fp32 / fast_q) < 1.1);
    }

    #[test]
    fn ofob_matches_gpipe_total_time_uniform() {
        // for uniform stages and zero comm, 1F1B and GPipe have equal
        // flush time (same critical path), only memory differs
        let g = SimConfig::uniform(4, 8, 0.01, 0.02, 0, 0, 1e12);
        let o = SimConfig { schedule: Schedule::OneFOneB, ..g.clone() };
        let tg = PipelineSim::run(&g).step_time_s;
        let to = PipelineSim::run(&o).step_time_s;
        assert!((tg - to).abs() < 1e-6, "{tg} vs {to}");
    }

    #[test]
    fn bytes_accounted() {
        let cfg = SimConfig::uniform(3, 4, 0.01, 0.01, 1000, 500, 1e9);
        let r = PipelineSim::run(&cfg);
        assert_eq!(r.fw_link_bytes, vec![4000, 4000]);
        assert_eq!(r.bw_link_bytes, vec![2000, 2000]);
    }

    #[test]
    fn allreduce_scaling() {
        assert_eq!(PipelineSim::allreduce_time(1000, 1, 1e9, 0.0), 0.0);
        let t2 = PipelineSim::allreduce_time(1_000_000, 2, 1e9, 0.0);
        let t8 = PipelineSim::allreduce_time(1_000_000, 8, 1e9, 0.0);
        assert!(t8 > t2); // 2(r-1)/r grows with r
        assert!(t8 < 2.0 * t2);
    }

    #[test]
    fn ring_allgather_scaling() {
        assert_eq!(PipelineSim::ring_allgather_time(1000, 1, 1e9, 0.0), 0.0);
        // d-1 hop rounds, each one frame transmission + latency
        let t = PipelineSim::ring_allgather_time(1_000_000, 4, 8e6, 0.001);
        assert!((t - 3.0 * (1.0 + 0.001)).abs() < 1e-9, "{t}");
    }
}
