//! Serving front-end macro-bench: one in-process `run_serve` fleet
//! (256 sessions x 4 requests, mixed inference + fine-tune, 2 shared
//! server stages, cross-session batching on) measured once, then the
//! operator-facing numbers — p50/p99 per-request round-trip latency and
//! aggregate per-row cost — recorded as time-only results. Unlike the
//! micro suites these are not resampled closures: the fleet run IS the
//! sample, and `BenchSuite::record` folds its observations into the
//! same schema-1 JSON the CI `bench-diff` gate consumes. §Perf target:
//! the session layer must not hide the compression wins — per-row cost
//! stays microseconds-scale while a 100 mbps link would spend
//! milliseconds per uncompressed row.
//!
//! Names and the fleet size are identical in `--quick` and full mode
//! (one macro run either way), so quick-mode JSON is comparable against
//! `BENCH_BASELINE_SERVE.json`.

use std::time::Duration;

use aq_sgd::serve::batch::BatchCfg;
use aq_sgd::serve::{run_serve, ServeConfig};
use aq_sgd::testing::bench::BenchSuite;

fn main() {
    let mut s = BenchSuite::from_args("bench_serve");

    let cfg = ServeConfig {
        sessions: 256,
        server_stages: 2,
        example_len: 8,
        shard: 2,
        epochs: 2,
        infer_every: 4,
        batch: BatchCfg { rows: 16, max_wait: Duration::from_micros(200) },
        workers: 4,
        ..ServeConfig::default()
    };
    let report = run_serve(&cfg).expect("serve bench fleet");

    // A shed or rejected fleet would report flattering latencies for
    // less work; the bench is only meaningful at full service.
    assert_eq!(report.rejected_sessions(), 0, "bench fleet must be fully admitted");
    assert_eq!(report.shed_total(), 0, "bench fleet must not be shed");
    let expect_rows = (cfg.sessions * cfg.shard * cfg.epochs) as u64;
    assert_eq!(report.replied_rows(), expect_rows, "every request must be replied");

    let p50 = report.latency_ns_percentile(0.50).expect("p50");
    let p99 = report.latency_ns_percentile(0.99).expect("p99");
    s.record("serve/256x4/latency_p50", p50 as f64);
    s.record("serve/256x4/latency_p99", p99 as f64);
    s.record("serve/256x4/ns_per_row", report.wall_s * 1e9 / expect_rows as f64);
    println!(
        "bench serve fleet: {} rows in {:.3} s ({:.0} rows/s, {} batches, {} padded rows)",
        expect_rows,
        report.wall_s,
        report.rows_per_s(),
        report.gateway.batches,
        report.gateway.padded_rows
    );

    s.finish().unwrap();
}
