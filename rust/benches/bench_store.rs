//! Activation-store benchmarks (paper §3.3 + App. G): buffer get/put for
//! memory, quantized-memory and disk backends, plus prefetch overlap.
//! The paper's claim: loading m(ξ) (0.2 ms mem / 12 ms SSD for GPT2-XL
//! records) hides behind a 44 ms forward pass.

use aq_sgd::store::{ActivationStore, DiskStore, MemStore, Prefetcher, QuantizedMemStore};
use aq_sgd::testing::bench::{black_box, BenchSuite};
use aq_sgd::util::Rng;

fn bench_store(s: &mut BenchSuite, name: &str, store: &mut dyn ActivationStore, record_len: usize) {
    let mut rng = Rng::new(2);
    let rec: Vec<f32> = (0..record_len).map(|_| rng.normal()).collect();
    for ex in 0..64u64 {
        store.put((0, ex), &rec);
    }
    let bytes = (record_len * 4) as u64;
    let mut out = Vec::new();
    let mut ex = 0u64;
    s.run_throughput(&format!("{name}/get"), bytes, || {
        black_box(store.get((0, ex % 64), &mut out));
        ex += 1;
    });
    s.run_throughput(&format!("{name}/put"), bytes, || {
        store.put((0, ex % 64), &rec);
        ex += 1;
    });
}

fn main() {
    let mut s = BenchSuite::from_args("bench_store");
    // paper-regime record: seq 1024 x d 1600 = 1.6M floats; here a small
    // (seq 64 x d 128) and a large record
    for record_len in [64 * 128usize, 512 * 1024] {
        println!("record = {} KiB", record_len * 4 / 1024);
        bench_store(
            &mut s,
            &format!("mem/{record_len}"),
            &mut MemStore::new(record_len),
            record_len,
        );
        bench_store(
            &mut s,
            &format!("quant8/{record_len}"),
            &mut QuantizedMemStore::new(record_len, 8),
            record_len,
        );
        let dir = std::env::temp_dir().join(format!("aqsgd_bench_store_{}", std::process::id()));
        bench_store(
            &mut s,
            &format!("disk/{record_len}"),
            &mut DiskStore::new(&dir, record_len).unwrap(),
            record_len,
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    // prefetch: overlapping fetch with "compute"
    let record_len = 64 * 128;
    let mut mem = MemStore::new(record_len);
    let mut rng = Rng::new(3);
    let rec: Vec<f32> = (0..record_len).map(|_| rng.normal()).collect();
    for ex in 0..64u64 {
        mem.put((0, ex), &rec);
    }
    let pf = Prefetcher::new(Box::new(mem));
    let mut ex = 0u64;
    s.run("prefetcher/request+collect", || {
        pf.request(vec![(0, ex % 64)]);
        black_box(pf.collect());
        ex += 1;
    });

    s.finish().unwrap();
}
