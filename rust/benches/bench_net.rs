//! Transport benchmarks: one-way frame delivery through the in-process
//! channel link (`net::frame_link`) vs a real loopback TCP socket pair
//! under the `IoDriver` (`net::tcp`). Same `FrameTx`/`FrameRx` contract,
//! same frames — the delta is the cost of the length-prefixed stream,
//! the reassembler, and two real socket syscalls per frame. §Perf
//! target: unshaped loopback TCP must stay far above slow-network
//! speeds, so the transport never hides the compression wins the paper
//! measures (a 100 mbps link moves 64 KB in ~5 ms; loopback should be
//! orders of magnitude faster).

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use aq_sgd::codec::frame::{Frame, TAG_RAW32};
use aq_sgd::net::tcp::IoDriver;
use aq_sgd::net::{frame_link, FrameRx, FrameTx, LinkShape};
use aq_sgd::testing::bench::{black_box, BenchSuite};

fn loopback_pair() -> (TcpStream, TcpStream) {
    let l = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = l.local_addr().expect("addr");
    let a = TcpStream::connect(addr).expect("connect");
    let (b, _) = l.accept().expect("accept");
    (a, b)
}

fn label(payload: usize) -> &'static str {
    match payload {
        1024 => "1KB",
        65536 => "64KB",
        _ => unreachable!("unlabeled payload size"),
    }
}

fn main() {
    let mut s = BenchSuite::from_args("bench_net");
    for payload in [1024usize, 65536] {
        let frame = Frame::new(TAG_RAW32, vec![0, 1], vec![0x5A; payload]).to_bytes();
        let wire = frame.len() as u64;

        // in-process channel link, unshaped (the executor-twin hot path)
        let (mut tx, mut rx) = frame_link(f64::INFINITY, Duration::ZERO);
        s.run_throughput(&format!("net/frame_link/{}", label(payload)), wire, || {
            FrameTx::send(&mut tx, frame.clone()).unwrap();
            black_box(rx.recv().unwrap());
        });

        // real loopback TCP under the I/O driver, unshaped
        let driver = IoDriver::new();
        let (sock_a, sock_b) = loopback_pair();
        let (mut ttx, _arx) = driver.register(sock_a, LinkShape::default()).unwrap();
        let (_btx, mut trx) = driver.register(sock_b, LinkShape::default()).unwrap();
        s.run_throughput(&format!("net/tcp_loopback/{}", label(payload)), wire, || {
            ttx.send(frame.clone()).unwrap();
            black_box(trx.recv().unwrap());
        });
    }
    s.finish().unwrap();
}
