//! Codec micro-benchmarks: the L3 hot path. A boundary message for the
//! paper regime is 1.6M elements; the coordinator must encode+pack well
//! above network speed so compression never becomes the bottleneck
//! (§Perf target: the fused quantize+pack kernels run multi-GB/s per
//! core — toward memory bandwidth, not the old >= 1 GB/s floor — and
//! the `quantize_pack_par` rows scale further across a worker pool with
//! bit-identical output at any worker count).
//!
//! This is the suite `BENCH_BASELINE.json` pins: run with
//! `-- --quick --json bench.json` for the machine-readable report the
//! CI `bench-diff` job compares. Names and problem sizes are identical
//! in quick and full mode.

use aq_sgd::codec::delta::AqState;
use aq_sgd::codec::frame::{FrameBuf, FrameView};
use aq_sgd::codec::par::Workers;
use aq_sgd::codec::quantizer::{Rounding, UniformQuantizer};
use aq_sgd::codec::registry::{build_mem_pair, SchemeSpec};
use aq_sgd::codec::{f16, pack, topk};
use aq_sgd::testing::bench::{black_box, BenchSuite};
use aq_sgd::util::Rng;

fn main() {
    let mut s = BenchSuite::from_args("bench_codec");
    let n = 1 << 20; // 1M elements = 4 MB fp32
    let bytes = (n * 4) as u64;
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

    // quantize (deterministic + stochastic)
    for rounding in [Rounding::Nearest, Rounding::Stochastic] {
        for bits in [2u8, 4, 8] {
            let q = UniformQuantizer::new(bits, rounding);
            let mut codes = vec![0u8; n];
            let name = format!("quantize/{bits}bit/{rounding:?}/1M");
            s.run_throughput(&name, bytes, || {
                black_box(q.encode(&x, &mut codes, &mut rng));
            });
        }
    }

    // fused quantize+pack (no u8 staging buffer) — the hot path the
    // DirectQ / AQ / EF codecs actually run per message
    for rounding in [Rounding::Nearest, Rounding::Stochastic] {
        for bits in [2u8, 4, 8] {
            let q = UniformQuantizer::new(bits, rounding);
            let pool = Workers::seq();
            let mut packed = vec![0u8; pack::packed_len(n, bits)];
            let name = format!("quantize_pack_fused/{bits}bit/{rounding:?}/1M");
            s.run_throughput(&name, bytes, || {
                black_box(q.encode_packed_into(&x, &mut packed, &mut rng, &pool).unwrap());
            });
        }
    }

    // fused unpack+dequantize
    {
        let q = UniformQuantizer::new(4, Rounding::Nearest);
        let pool = Workers::seq();
        let mut packed = vec![0u8; pack::packed_len(n, 4)];
        let scale = q.encode_packed_into(&x, &mut packed, &mut rng, &pool).unwrap();
        let mut out = vec![0f32; n];
        s.run_throughput("dequantize_fused/4bit/1M", bytes, || {
            q.decode_packed(&packed, scale, &mut out, &pool);
            black_box(&out);
        });
    }

    // deterministic parallel fused encode: identical bytes at every
    // worker count, throughput scales with the pool
    for w in [1usize, 2, 4] {
        let q = UniformQuantizer::new(4, Rounding::Nearest);
        let pool = Workers::new(w);
        let mut packed = vec![0u8; pack::packed_len(n, 4)];
        s.run_throughput(&format!("quantize_pack_par/4bit/1M/w{w}"), bytes, || {
            black_box(q.encode_packed_into(&x, &mut packed, &mut rng, &pool).unwrap());
        });
    }

    // dequantize
    let q = UniformQuantizer::new(4, Rounding::Nearest);
    let mut codes = vec![0u8; n];
    let scale = q.encode(&x, &mut codes, &mut rng);
    let mut out = vec![0f32; n];
    s.run_throughput("dequantize/4bit/1M", bytes, || {
        q.decode(&codes, scale, &mut out);
        black_box(&out);
    });

    // bit packing
    for bits in [2u8, 3, 4, 8] {
        let mut packed = vec![0u8; pack::packed_len(n, bits)];
        s.run_throughput(&format!("pack/{bits}bit/1M"), n as u64, || {
            pack::pack_into(&codes, bits, &mut packed);
            black_box(&packed);
        });
        let mut unpacked = vec![0u8; n];
        s.run_throughput(&format!("unpack/{bits}bit/1M"), n as u64, || {
            pack::unpack_into(&packed, bits, &mut unpacked);
            black_box(&unpacked);
        });
    }

    // full AQ-SGD boundary encode (delta + quant + buffer advance)
    let st = AqState::new(4, Rounding::Nearest);
    let m: Vec<f32> = x.iter().map(|v| v + 0.01).collect();
    let mut m_out = Vec::with_capacity(n);
    s.run_throughput("aq_encode/4bit/1M", bytes, || {
        black_box(st.encode(&x, Some(&m), &mut m_out, &mut rng));
    });

    // fp16 wire
    let mut wire = Vec::new();
    s.run_throughput("f16_encode/1M", bytes, || {
        f16::encode(&x, &mut wire);
        black_box(&wire);
    });

    // top-k (split-learning backward)
    s.run_throughput("topk20%/8bit/64k", 65536 * 4, || {
        black_box(topk::encode(&x[..65536], 0.2, 8, &mut rng));
    });

    // ---- registry-driven: full frame encode/decode per scheme ----
    // Every registered scheme through the real BoundaryCodec path, both
    // the allocating form (encode -> Frame -> decode) and the scratch
    // hot path (encode_into -> FrameBuf, FrameView -> decode_into) the
    // executors run in steady state.
    let el = 1 << 18; // 256k elements = 1 MB fp32 message
    let reg_bytes = (el * 4) as u64;
    let ids = [0u64];
    let a = &x[..el];
    let a2: Vec<f32> = a.iter().map(|v| v + 1e-3).collect();
    let mut specs: Vec<String> = vec!["fp32".into(), "fp16".into()];
    for bits in [2u8, 4, 8] {
        specs.push(format!("q{bits}"));
        specs.push(format!("aq{bits}"));
        specs.push(format!("topk0.2@{bits}"));
        specs.push(format!("ef:q{bits}"));
    }
    // the adaptive family (tile / had / lr), one representative each
    // plus the composed Hadamard-rotated tile quantizer
    for spec in ["tile:64:q4", "had:q4", "had:tile:64:q4", "lr:4:q4"] {
        specs.push(spec.into());
    }
    for spec in specs {
        let scheme = SchemeSpec::parse(&spec).unwrap();
        let (mut enc, mut dec) = build_mem_pair(&scheme, el, Rounding::Nearest, 9).unwrap();
        // warm both halves' AQ buffers through the first-visit frame
        let first = enc.encode(&ids, a).unwrap();
        dec.decode(&ids, &first).unwrap();
        s.run_throughput(&format!("frame_encode/{spec}/1MB"), reg_bytes, || {
            black_box(enc.encode(&ids, &a2).unwrap());
        });
        let frame = enc.encode(&ids, &a2).unwrap();
        s.run_throughput(&format!("frame_decode/{spec}/1MB"), reg_bytes, || {
            black_box(dec.decode(&ids, &frame).unwrap());
        });

        // scratch path: separate halves so warmed capacities persist
        let (mut enc2, mut dec2) = build_mem_pair(&scheme, el, Rounding::Nearest, 9).unwrap();
        let mut buf = FrameBuf::new();
        let mut out = vec![0f32; el];
        enc2.encode_into(&ids, a, &mut buf).unwrap();
        dec2.decode_into(&ids, &FrameView::parse(buf.as_bytes()).unwrap(), &mut out).unwrap();
        s.run_throughput(&format!("frame_encode_into/{spec}/1MB"), reg_bytes, || {
            enc2.encode_into(&ids, &a2, &mut buf).unwrap();
            black_box(buf.as_bytes());
        });
        enc2.encode_into(&ids, &a2, &mut buf).unwrap();
        let wire: Vec<u8> = buf.as_bytes().to_vec();
        s.run_throughput(&format!("frame_decode_into/{spec}/1MB"), reg_bytes, || {
            let view = FrameView::parse(&wire).unwrap();
            dec2.decode_into(&ids, &view, &mut out).unwrap();
            black_box(&out);
        });
    }

    s.finish().unwrap();
}
