//! Codec micro-benchmarks: the L3 hot path. A boundary message for the
//! paper regime is 1.6M elements; the coordinator must encode+pack well
//! above network speed so compression never becomes the bottleneck
//! (§Perf target: >= 1 GB/s per core).

use aq_sgd::codec::delta::AqState;
use aq_sgd::codec::quantizer::{Rounding, UniformQuantizer};
use aq_sgd::codec::{f16, pack, topk};
use aq_sgd::testing::bench::{black_box, Bencher};
use aq_sgd::util::Rng;

fn main() {
    let b = Bencher::default();
    let n = 1 << 20; // 1M elements = 4 MB fp32
    let bytes = (n * 4) as u64;
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

    // quantize (deterministic + stochastic)
    for rounding in [Rounding::Nearest, Rounding::Stochastic] {
        for bits in [2u8, 4, 8] {
            let q = UniformQuantizer::new(bits, rounding);
            let mut codes = vec![0u8; n];
            let name = format!("quantize/{bits}bit/{rounding:?}/1M");
            b.run(&name, || {
                black_box(q.encode(&x, &mut codes, &mut rng));
            })
            .report_throughput(bytes);
        }
    }

    // dequantize
    let q = UniformQuantizer::new(4, Rounding::Nearest);
    let mut codes = vec![0u8; n];
    let scale = q.encode(&x, &mut codes, &mut rng);
    let mut out = vec![0f32; n];
    b.run("dequantize/4bit/1M", || {
        q.decode(&codes, scale, &mut out);
        black_box(&out);
    })
    .report_throughput(bytes);

    // bit packing
    for bits in [2u8, 3, 4, 8] {
        let mut packed = vec![0u8; pack::packed_len(n, bits)];
        b.run(&format!("pack/{bits}bit/1M"), || {
            pack::pack_into(&codes, bits, &mut packed);
            black_box(&packed);
        })
        .report_throughput(n as u64);
        let mut unpacked = vec![0u8; n];
        b.run(&format!("unpack/{bits}bit/1M"), || {
            pack::unpack_into(&packed, bits, &mut unpacked);
            black_box(&unpacked);
        })
        .report_throughput(n as u64);
    }

    // full AQ-SGD boundary encode (delta + quant + buffer advance)
    let st = AqState::new(4, Rounding::Nearest);
    let m: Vec<f32> = x.iter().map(|v| v + 0.01).collect();
    let mut m_out = Vec::with_capacity(n);
    b.run("aq_encode/4bit/1M", || {
        black_box(st.encode(&x, Some(&m), &mut m_out, &mut rng));
    })
    .report_throughput(bytes);

    // fp16 wire
    let mut wire = Vec::new();
    b.run("f16_encode/1M", || {
        f16::encode(&x, &mut wire);
        black_box(&wire);
    })
    .report_throughput(bytes);

    // top-k (split-learning backward)
    b.run("topk20%/8bit/64k", || {
        black_box(topk::encode(&x[..65536], 0.2, 8, &mut rng));
    })
    .report_throughput(65536 * 4);
}
