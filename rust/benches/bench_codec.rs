//! Codec micro-benchmarks: the L3 hot path. A boundary message for the
//! paper regime is 1.6M elements; the coordinator must encode+pack well
//! above network speed so compression never becomes the bottleneck
//! (§Perf target: >= 1 GB/s per core).

use aq_sgd::codec::delta::AqState;
use aq_sgd::codec::quantizer::{Rounding, UniformQuantizer};
use aq_sgd::codec::registry::{build_mem_pair, SchemeSpec};
use aq_sgd::codec::{f16, pack, topk};
use aq_sgd::testing::bench::{black_box, Bencher};
use aq_sgd::util::Rng;

fn main() {
    let b = Bencher::default();
    let n = 1 << 20; // 1M elements = 4 MB fp32
    let bytes = (n * 4) as u64;
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

    // quantize (deterministic + stochastic)
    for rounding in [Rounding::Nearest, Rounding::Stochastic] {
        for bits in [2u8, 4, 8] {
            let q = UniformQuantizer::new(bits, rounding);
            let mut codes = vec![0u8; n];
            let name = format!("quantize/{bits}bit/{rounding:?}/1M");
            b.run(&name, || {
                black_box(q.encode(&x, &mut codes, &mut rng));
            })
            .report_throughput(bytes);
        }
    }

    // dequantize
    let q = UniformQuantizer::new(4, Rounding::Nearest);
    let mut codes = vec![0u8; n];
    let scale = q.encode(&x, &mut codes, &mut rng);
    let mut out = vec![0f32; n];
    b.run("dequantize/4bit/1M", || {
        q.decode(&codes, scale, &mut out);
        black_box(&out);
    })
    .report_throughput(bytes);

    // bit packing
    for bits in [2u8, 3, 4, 8] {
        let mut packed = vec![0u8; pack::packed_len(n, bits)];
        b.run(&format!("pack/{bits}bit/1M"), || {
            pack::pack_into(&codes, bits, &mut packed);
            black_box(&packed);
        })
        .report_throughput(n as u64);
        let mut unpacked = vec![0u8; n];
        b.run(&format!("unpack/{bits}bit/1M"), || {
            pack::unpack_into(&packed, bits, &mut unpacked);
            black_box(&unpacked);
        })
        .report_throughput(n as u64);
    }

    // full AQ-SGD boundary encode (delta + quant + buffer advance)
    let st = AqState::new(4, Rounding::Nearest);
    let m: Vec<f32> = x.iter().map(|v| v + 0.01).collect();
    let mut m_out = Vec::with_capacity(n);
    b.run("aq_encode/4bit/1M", || {
        black_box(st.encode(&x, Some(&m), &mut m_out, &mut rng));
    })
    .report_throughput(bytes);

    // fp16 wire
    let mut wire = Vec::new();
    b.run("f16_encode/1M", || {
        f16::encode(&x, &mut wire);
        black_box(&wire);
    })
    .report_throughput(bytes);

    // top-k (split-learning backward)
    b.run("topk20%/8bit/64k", || {
        black_box(topk::encode(&x[..65536], 0.2, 8, &mut rng));
    })
    .report_throughput(65536 * 4);

    // ---- registry-driven: full frame encode/decode per scheme ----
    // Every registered scheme through the real BoundaryCodec path
    // (encode -> Frame, Frame -> decode), at the paper's bit widths.
    let el = 1 << 18; // 256k elements = 1 MB fp32 message
    let reg_bytes = (el * 4) as u64;
    let ids = [0u64];
    let a = &x[..el];
    let a2: Vec<f32> = a.iter().map(|v| v + 1e-3).collect();
    let mut specs: Vec<String> = vec!["fp32".into(), "fp16".into()];
    for bits in [2u8, 4, 8] {
        specs.push(format!("q{bits}"));
        specs.push(format!("aq{bits}"));
        specs.push(format!("topk0.2@{bits}"));
        specs.push(format!("ef:q{bits}"));
    }
    for spec in specs {
        let scheme = SchemeSpec::parse(&spec).unwrap();
        let (mut enc, mut dec) = build_mem_pair(&scheme, el, Rounding::Nearest, 9).unwrap();
        // warm both halves' AQ buffers through the first-visit frame
        let first = enc.encode(&ids, a).unwrap();
        dec.decode(&ids, &first).unwrap();
        b.run(&format!("frame_encode/{spec}/1MB"), || {
            black_box(enc.encode(&ids, &a2).unwrap());
        })
        .report_throughput(reg_bytes);
        let frame = enc.encode(&ids, &a2).unwrap();
        b.run(&format!("frame_decode/{spec}/1MB"), || {
            black_box(dec.decode(&ids, &frame).unwrap());
        })
        .report_throughput(reg_bytes);
    }
}
