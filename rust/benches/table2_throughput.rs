//! `cargo bench` entry regenerating the paper's throughput tables
//! (Tables 2 and 5) plus Figure 4's bandwidth sweep, via the virtual-time
//! simulator in the paper regime. Fast (pure simulation) — the heavier
//! convergence counterparts live in examples/.

use aq_sgd::codec::CodecSpec;
use aq_sgd::exp::PaperRegime;
use aq_sgd::metrics::Table;
use aq_sgd::net::PAPER_BANDWIDTHS;
use aq_sgd::pipeline::{PipelineSim, Schedule, SimConfig};

fn throughput(r: &PaperRegime, c: &CodecSpec, bw: f64, schedule: Schedule) -> f64 {
    let (fw, bwb) = r.msg_bytes(c, false);
    let cfg = SimConfig {
        schedule,
        ..SimConfig::uniform(r.n_stages, r.n_micro, r.fwd_s, r.bwd_s, fw, bwb, bw)
    };
    PipelineSim::run(&cfg).throughput(r.n_micro, r.micro_batch)
}

fn main() {
    let regime = PaperRegime::default();
    println!("== Table 2: GPT2-1.5B training throughput (seqs/s) ==\n");
    let mut t = Table::new(&["Network", "FP32", "DirectQ fw3bw6/fw4bw8", "AQ-SGD fw3bw6/fw4bw8"]);
    for (bw, label) in PAPER_BANDWIDTHS {
        let fp32 = throughput(&regime, &CodecSpec::fp32(), bw, Schedule::GPipe);
        let f = |fw_bits, bw_bits| {
            (
                throughput(&regime, &CodecSpec::directq(fw_bits, bw_bits), bw, Schedule::GPipe),
                throughput(&regime, &CodecSpec::aqsgd(fw_bits, bw_bits), bw, Schedule::GPipe),
            )
        };
        let (d36, a36) = f(3, 6);
        let (d48, a48) = f(4, 8);
        t.row(vec![
            label.to_string(),
            format!("{fp32:.1}"),
            format!("{d36:.1} / {d48:.1}"),
            format!("{a36:.1} / {a48:.1}"),
        ]);
    }
    print!("{}", t.render());

    println!("\n== ablation: schedule (GPipe vs 1F1B) at fw4 bw8 ==\n");
    let mut ts = Table::new(&["Network", "GPipe", "1F1B", "peak in-flight (stage0)"]);
    let c = CodecSpec::aqsgd(4, 8);
    for (bw, label) in PAPER_BANDWIDTHS {
        let g = throughput(&regime, &c, bw, Schedule::GPipe);
        let o = throughput(&regime, &c, bw, Schedule::OneFOneB);
        ts.row(vec![
            label.to_string(),
            format!("{g:.1}"),
            format!("{o:.1}"),
            format!(
                "{} vs {}",
                Schedule::GPipe.peak_in_flight(0, regime.n_stages, regime.n_micro),
                Schedule::OneFOneB.peak_in_flight(0, regime.n_stages, regime.n_micro)
            ),
        ]);
    }
    print!("{}", ts.render());

    // sanity assertions so `cargo bench` acts as a regression gate on the
    // paper's shape: FP32 collapses with bandwidth, AQ-SGD stays flat.
    let fp32_fast = throughput(&regime, &CodecSpec::fp32(), 10e9, Schedule::GPipe);
    let fp32_slow = throughput(&regime, &CodecSpec::fp32(), 100e6, Schedule::GPipe);
    let aq_slow = throughput(
        &regime,
        &CodecSpec::aqsgd(4, 8),
        100e6,
        Schedule::GPipe,
    );
    assert!(fp32_fast / fp32_slow > 4.0, "FP32 should collapse on slow nets");
    assert!(aq_slow / fp32_slow > 3.0, "AQ-SGD speedup at 100 Mbps (paper: ~6x in seqs/s)");
    println!("\nshape checks passed: FP32 collapses {:.1}x, AQ-SGD wins {:.1}x at 100 Mbps",
        fp32_fast / fp32_slow, aq_slow / fp32_slow);
}
