//! Executor throughput: the virtual-clock numeric executor vs the real
//! threaded runtime vs the event (worker-pool) runtime on the same
//! config, across schedules and codecs.
//! §Perf target: the real runtimes' overhead (threads/pool + channels +
//! frame serialization) stays within the same order of magnitude as the
//! single-threaded numeric path at test-sized configs, and the event
//! executor holds that at topologies where thread-per-stage would need
//! an order of magnitude more OS threads.

use aq_sgd::codec::CodecSpec;
use aq_sgd::pipeline::exec::{run_events, run_threads, run_virtual, ExecConfig};
use aq_sgd::pipeline::Schedule;
use aq_sgd::testing::bench::{black_box, BenchSuite};

fn cfg(spec: &str, schedule: Schedule) -> ExecConfig {
    let mut c = ExecConfig::small(CodecSpec::parse(spec).unwrap());
    c.schedule = schedule;
    c.n_stages = 4;
    c.n_micro = 8;
    c.micro_batch = 2;
    c.example_len = 256;
    c.steps = 2;
    // effectively-infinite link speed: measure runtime overhead, not
    // modeled transmission sleeps
    c.bandwidth_bps = 1e12;
    c.latency_s = 0.0;
    c
}

/// The scale case: 64 stage tasks, where the executors' structural
/// difference (64 OS threads vs a 4-worker pool) actually shows.
fn large_cfg() -> ExecConfig {
    let mut c = cfg("aqsgd:fw2bw4", Schedule::OneFOneB);
    c.n_stages = 64;
    c.n_micro = 2;
    c.micro_batch = 1;
    c.example_len = 16;
    c.steps = 1;
    c.workers = 4;
    c
}

fn main() {
    let mut s = BenchSuite::from_args("bench_exec");
    for schedule in [Schedule::GPipe, Schedule::OneFOneB] {
        for spec in ["fp32", "aqsgd:fw2bw4", "hybrid:aq2/topk0.2@8"] {
            let c = cfg(spec, schedule);
            s.run(&format!("exec/virtual/{spec}/{schedule:?}"), || {
                black_box(run_virtual(&c).unwrap());
            });
            s.run(&format!("exec/threads/{spec}/{schedule:?}"), || {
                black_box(run_threads(&c).unwrap());
            });
            s.run(&format!("exec/events/{spec}/{schedule:?}"), || {
                black_box(run_events(&c).unwrap());
            });
        }
    }

    // large topology: virtual vs threads vs a 4-worker event pool
    let lc = large_cfg();
    s.run("exec/large64/virtual", || {
        black_box(run_virtual(&lc).unwrap());
    });
    s.run("exec/large64/threads", || {
        black_box(run_threads(&lc).unwrap());
    });
    s.run("exec/large64/events-w4", || {
        black_box(run_events(&lc).unwrap());
    });

    // wire volume per step at bench size, for the report's context
    let c = cfg("aqsgd:fw2bw4", Schedule::GPipe);
    let t = run_virtual(&c).unwrap();
    let steady: u64 = t.steps.last().unwrap().fw_wire_bytes.iter().sum();
    println!("aq2 steady-state fw wire/step at bench size: {steady} B");

    s.finish().unwrap();
}
