//! Runtime benchmarks: per-stage artifact execution and the boundary
//! codec paths (native vs Pallas-HLO), i.e. the real per-microbatch cost
//! profile behind Table 3's "comp." columns on this host. Skips cleanly
//! if artifacts are missing.

use aq_sgd::codec::quantizer::Rounding;
use aq_sgd::codec::registry::{build_mem_pair, BuildCtx};
use aq_sgd::codec::CodecSpec;
use aq_sgd::coordinator::boundary::ForwardBoundary;
use aq_sgd::runtime::{Engine, QuantRuntime, StageInput, StageRuntime};
use aq_sgd::store::{ActivationStore, MemStore};
use aq_sgd::testing::bench::{black_box, Bencher};
use aq_sgd::testing::require_artifacts;
use aq_sgd::util::error::Result;
use aq_sgd::util::Rng;

fn main() {
    let Some(man) = require_artifacts("tiny") else {
        return; // require_artifacts already printed the consolidated notice
    };
    let b = Bencher::default();
    let engine = Engine::cpu().unwrap();
    let s0 = StageRuntime::load(&engine, &man, 0).unwrap();
    let s1 = StageRuntime::load(&engine, &man, 1).unwrap();
    let mut rng = Rng::new(4);
    let n_tok = man.micro_batch().unwrap() * man.seq().unwrap();
    let toks: Vec<i32> = (0..n_tok).map(|_| rng.below(man.vocab().unwrap()) as i32).collect();
    let h = s0.forward(&StageInput::Tokens(&toks)).unwrap();

    b.run("stage0_fwd/tiny", || {
        black_box(s0.forward(&StageInput::Tokens(&toks)).unwrap());
    })
    .report();
    b.run("stage1_lossbwd/tiny", || {
        black_box(s1.loss_backward(&StageInput::Hidden(&h), &toks).unwrap());
    })
    .report();
    let gx: Vec<f32> = h.iter().map(|v| v * 0.01).collect();
    b.run("stage0_bwd/tiny", || {
        black_box(s0.backward(&StageInput::Tokens(&toks), &gx).unwrap());
    })
    .report();

    // boundary codecs, native vs HLO (the Pallas kernels via PJRT)
    let n = man.boundary_len().unwrap();
    let el = man.example_len().unwrap();
    let ids: Vec<u64> = (0..man.micro_batch().unwrap() as u64).collect();
    let msg_bytes = (n * 4) as u64;

    let spec = CodecSpec::aqsgd(4, 8);
    let (enc, dec) = build_mem_pair(&spec.fw, el, Rounding::Nearest, 1).unwrap();
    let mut native = ForwardBoundary::new(0, el, enc, dec);
    native.transfer(&ids, &h).unwrap(); // warm the buffers
    b.run("boundary_native_aq4/16KiB", || {
        black_box(native.transfer(&ids, &h).unwrap());
    })
    .report_throughput(msg_bytes);

    let q = std::sync::Arc::new(QuantRuntime::load(&engine, &man).unwrap());
    let mut mk = |_role: &str| -> Result<Box<dyn ActivationStore>> {
        Ok(Box::new(MemStore::new(el)))
    };
    let (enc, dec) = spec
        .fw
        .build_pair(&mut BuildCtx {
            example_len: el,
            rounding: Rounding::Nearest,
            seed: 2,
            ns: 0,
            hlo: Some(q),
            mk_store: &mut mk,
        })
        .unwrap();
    let mut hlo = ForwardBoundary::new(0, el, enc, dec);
    hlo.transfer(&ids, &h).unwrap();
    b.run("boundary_hlo_aq4/16KiB", || {
        black_box(hlo.transfer(&ids, &h).unwrap());
    })
    .report_throughput(msg_bytes);
}
