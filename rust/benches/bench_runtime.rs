//! Runtime benchmarks: per-stage artifact execution and the boundary
//! codec paths (native vs Pallas-HLO), i.e. the real per-microbatch cost
//! profile behind Table 3's "comp." columns on this host. Skips cleanly
//! if artifacts are missing.

use aq_sgd::codec::quantizer::Rounding;
use aq_sgd::codec::registry::{build_mem_pair, BuildCtx};
use aq_sgd::codec::CodecSpec;
use aq_sgd::coordinator::boundary::ForwardBoundary;
use aq_sgd::runtime::{Engine, QuantRuntime, StageInput, StageRuntime};
use aq_sgd::store::{ActivationStore, MemStore};
use aq_sgd::testing::bench::{black_box, BenchSuite};
use aq_sgd::testing::require_artifacts;
use aq_sgd::util::error::Result;
use aq_sgd::util::Rng;

fn main() {
    let mut s = BenchSuite::from_args("bench_runtime");
    let Some(man) = require_artifacts("tiny") else {
        // require_artifacts already printed the consolidated notice; an
        // empty JSON report (if requested) keeps the pipeline well-formed
        s.finish().unwrap();
        return;
    };
    let engine = Engine::cpu().unwrap();
    let s0 = StageRuntime::load(&engine, &man, 0).unwrap();
    let s1 = StageRuntime::load(&engine, &man, 1).unwrap();
    let mut rng = Rng::new(4);
    let n_tok = man.micro_batch().unwrap() * man.seq().unwrap();
    let toks: Vec<i32> = (0..n_tok).map(|_| rng.below(man.vocab().unwrap()) as i32).collect();
    let h = s0.forward(&StageInput::Tokens(&toks)).unwrap();

    s.run("stage0_fwd/tiny", || {
        black_box(s0.forward(&StageInput::Tokens(&toks)).unwrap());
    });
    s.run("stage1_lossbwd/tiny", || {
        black_box(s1.loss_backward(&StageInput::Hidden(&h), &toks).unwrap());
    });
    let gx: Vec<f32> = h.iter().map(|v| v * 0.01).collect();
    s.run("stage0_bwd/tiny", || {
        black_box(s0.backward(&StageInput::Tokens(&toks), &gx).unwrap());
    });

    // boundary codecs, native vs HLO (the Pallas kernels via PJRT)
    let n = man.boundary_len().unwrap();
    let el = man.example_len().unwrap();
    let ids: Vec<u64> = (0..man.micro_batch().unwrap() as u64).collect();
    let msg_bytes = (n * 4) as u64;

    let spec = CodecSpec::aqsgd(4, 8);
    let (enc, dec) = build_mem_pair(&spec.fw, el, Rounding::Nearest, 1).unwrap();
    let mut native = ForwardBoundary::new(0, el, enc, dec);
    native.transfer(&ids, &h).unwrap(); // warm the buffers
    s.run_throughput("boundary_native_aq4/16KiB", msg_bytes, || {
        black_box(native.transfer(&ids, &h).unwrap());
    });

    let q = std::sync::Arc::new(QuantRuntime::load(&engine, &man).unwrap());
    let mut mk = |_role: &str| -> Result<Box<dyn ActivationStore>> {
        Ok(Box::new(MemStore::new(el)))
    };
    let (enc, dec) = spec
        .fw
        .build_pair(&mut BuildCtx {
            example_len: el,
            rounding: Rounding::Nearest,
            seed: 2,
            ns: 0,
            hlo: Some(q),
            mk_store: &mut mk,
        })
        .unwrap();
    let mut hlo = ForwardBoundary::new(0, el, enc, dec);
    hlo.transfer(&ids, &h).unwrap();
    s.run_throughput("boundary_hlo_aq4/16KiB", msg_bytes, || {
        black_box(hlo.transfer(&ids, &h).unwrap());
    });

    s.finish().unwrap();
}
