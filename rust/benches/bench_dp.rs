//! Data-parallel gradient-exchange benchmarks: the registry-driven ring
//! reduce (`DpGroup` over `net::plane::DpRing`) swept across replica
//! degree x gradient codec. §Perf target: the framed ring path (encode +
//! serialize + per-sender decode, plus EF residual upkeep) must stay
//! well above slow-network speed so gradient compression never becomes
//! the step bottleneck.

use aq_sgd::codec::{CodecSpec, Rounding};
use aq_sgd::coordinator::DpGroup;
use aq_sgd::testing::bench::{black_box, BenchSuite};
use aq_sgd::util::Rng;

fn main() {
    let mut s = BenchSuite::from_args("bench_dp");
    let n = 1 << 16; // 64k-element stage gradient (256 KB fp32)
    for degree in [2usize, 4, 8] {
        for spec in ["fp32", "ef:directq:fw2bw2", "ef:directq:fw4bw4", "ef:directq:fw8bw8"] {
            let cs = CodecSpec::parse(spec).unwrap();
            let mut dp = DpGroup::new(degree, &cs, &[n], Rounding::Nearest, 1).unwrap();
            let mut rng = Rng::new(7);
            let grads: Vec<Vec<Vec<f32>>> = (0..degree)
                .map(|_| vec![(0..n).map(|_| rng.normal() * 0.01).collect::<Vec<f32>>()])
                .collect();
            // warm one round so EF residuals exist (steady state)
            dp.reduce(&grads).unwrap();
            s.run_throughput(
                &format!("dp_reduce/{spec}/x{degree}/256KB"),
                (degree * n * 4) as u64,
                || {
                    black_box(dp.reduce(&grads).unwrap());
                },
            );
        }
    }

    // measured ring wire per codec, for the report's context
    let g: Vec<Vec<Vec<f32>>> = {
        let mut rng = Rng::new(9);
        (0..2).map(|_| vec![(0..n).map(|_| rng.normal() * 0.01).collect::<Vec<f32>>()]).collect()
    };
    for spec in ["fp32", "ef:directq:fw4bw4"] {
        let cs = CodecSpec::parse(spec).unwrap();
        let mut dp = DpGroup::new(2, &cs, &[n], Rounding::Nearest, 1).unwrap();
        let (_, wire) = dp.reduce(&g).unwrap();
        println!("{spec}: {} B on the ring per step (x2 replicas)", wire.total_bytes);
    }

    s.finish().unwrap();
}
