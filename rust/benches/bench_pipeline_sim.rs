//! Virtual-time simulator throughput: the table/figure harnesses sweep
//! thousands of (bandwidth x scheme x schedule) cells; each cell is one
//! `PipelineSim::run`. §Perf target: >= 10^6 simulated ops/s.

use aq_sgd::pipeline::{PipelineSim, Schedule, SimConfig};
use aq_sgd::testing::bench::{black_box, BenchSuite};

fn main() {
    let mut s = BenchSuite::from_args("bench_pipeline_sim");
    for (k, m) in [(2usize, 8usize), (8, 32), (8, 128)] {
        let ops = (2 * k * m) as f64;
        for schedule in [Schedule::GPipe, Schedule::OneFOneB] {
            let cfg = SimConfig {
                schedule,
                ..SimConfig::uniform(k, m, 0.045, 0.135, 800_000, 1_600_000, 100e6)
            };
            let r = s.run(&format!("sim/K{k}/M{m}/{schedule:?}"), || {
                black_box(PipelineSim::run(&cfg));
            });
            println!(
                "      -> {:.2} M ops/s",
                ops / r.mean_ns * 1e3
            );
        }
    }

    // a full Table 2 sweep (5 bandwidths x 4 schemes)
    let cfg0 = SimConfig::uniform(8, 32, 0.045, 0.135, 6_400_000, 6_400_000, 100e6);
    s.run("table2_full_sweep/20cells", || {
        for bw in [10e9, 1e9, 500e6, 300e6, 100e6] {
            for div in [1u64, 8, 10, 16] {
                let cfg = SimConfig {
                    bandwidth_bps: bw,
                    fw_bytes: vec![6_400_000 / div; 32],
                    bw_bytes: 6_400_000 / div,
                    ..cfg0.clone()
                };
                black_box(PipelineSim::run(&cfg));
            }
        }
    });

    s.finish().unwrap();
}
