//! Threaded two-machine pipeline over real-sleep simulated links: the
//! "deployment realism" check. Machine a (stage 0) and machine b (stage
//! 1) run on separate OS threads, exchange AQ-SGD messages over
//! `net::RealLink`s with finite bandwidth, and must produce exactly the
//! numbers the sequential coordinator produces.

use std::time::{Duration, Instant};

use aq_sgd::codec::delta::{AqMessage, AqState};
use aq_sgd::codec::quantizer::Rounding;
use aq_sgd::net::RealLink;
use aq_sgd::runtime::{Engine, Manifest, StageInput, StageRuntime};
use aq_sgd::testing::{artifacts_root, require_artifacts};
use aq_sgd::util::Rng;

/// Wire form of a forward AQ message + the example's backward reply.
enum FwMsg {
    Activation(AqMessage),
    Done,
}

#[test]
fn threaded_two_machine_pipeline_matches_sequential() {
    let Some(man) = require_artifacts("tiny") else {
        return; // require_artifacts printed the consolidated skip notice
    };
    let micro_b = man.micro_batch().unwrap();
    let seq = man.seq().unwrap();
    let vocab = man.vocab().unwrap();
    let n_steps = 3usize;
    let bits = 4u8;

    // fixed token stream shared by both runs
    let mut rng = Rng::new(99);
    let batches: Vec<Vec<i32>> = (0..n_steps)
        .map(|_| (0..micro_b * seq).map(|_| rng.below(vocab) as i32).collect())
        .collect();

    // ---------- sequential reference ----------
    let seq_losses: Vec<f32> = {
        let engine = Engine::cpu().unwrap();
        let s0 = StageRuntime::load(&engine, &man, 0).unwrap();
        let s1 = StageRuntime::load(&engine, &man, 1).unwrap();
        let aq = AqState::new(bits, Rounding::Nearest);
        let mut m_send: Vec<Option<Vec<f32>>> = vec![None; n_steps];
        let mut m_recv: Vec<Option<Vec<f32>>> = vec![None; n_steps];
        let mut rng = Rng::new(0);
        batches
            .iter()
            .enumerate()
            .map(|(i, toks)| {
                let h = s0.forward(&StageInput::Tokens(toks)).unwrap();
                let mut ms = Vec::new();
                let msg = aq.encode(&h, m_send[i].as_deref(), &mut ms, &mut rng);
                let mut mr = Vec::new();
                aq.decode(&msg, m_recv[i].as_deref(), &mut mr).unwrap();
                m_send[i] = Some(ms);
                let (loss, _, _) = s1.loss_backward(&StageInput::Hidden(&mr), toks).unwrap();
                m_recv[i] = Some(mr);
                loss
            })
            .collect()
    };

    // ---------- threaded run over real-sleep links ----------
    // 8 Mbps => a 16 KiB fp32 message takes ~16 ms: enough to observe
    // pacing without slowing the test down.
    let (mut fw_tx, fw_rx) = RealLink::<FwMsg>::channel(8e6, Duration::from_millis(1));
    let (mut bw_tx, bw_rx) = RealLink::<Vec<f32>>::channel(8e6, Duration::from_millis(1));

    let batches_a = batches.clone();
    let machine_a = std::thread::spawn(move || {
        let engine = Engine::cpu().unwrap();
        let s0 = StageRuntime::load(&engine, &man, 0).unwrap();
        let aq = AqState::new(bits, Rounding::Nearest);
        let mut stores: Vec<Option<Vec<f32>>> = vec![None; batches_a.len()];
        let mut rng = Rng::new(0);
        for (i, toks) in batches_a.iter().enumerate() {
            let h = s0.forward(&StageInput::Tokens(toks)).unwrap();
            let mut m_new = Vec::new();
            let msg = aq.encode(&h, stores[i].as_deref(), &mut m_new, &mut rng);
            let bytes = msg.wire_bytes(bits);
            stores[i] = Some(m_new);
            fw_tx.send(FwMsg::Activation(msg), bytes);
            // consume the backward gradient (machine a would run bwd here)
            let g = bw_rx.recv().unwrap();
            assert!(g.iter().all(|v| v.is_finite()));
        }
        fw_tx.send(FwMsg::Done, 1);
    });

    let man_b = Manifest::load(artifacts_root(), "tiny").unwrap();
    let batches_b = batches.clone();
    let machine_b = std::thread::spawn(move || {
        let engine = Engine::cpu().unwrap();
        let s1 = StageRuntime::load(&engine, &man_b, 1).unwrap();
        let aq = AqState::new(bits, Rounding::Nearest);
        let mut stores: Vec<Option<Vec<f32>>> = vec![None; batches_b.len()];
        let mut losses = Vec::new();
        let mut i = 0usize;
        while let Some(msg) = fw_rx.recv() {
            let msg = match msg {
                FwMsg::Done => break,
                FwMsg::Activation(m) => m,
            };
            let mut m_new = Vec::new();
            aq.decode(&msg, stores[i].as_deref(), &mut m_new).unwrap();
            let (loss, _, gx) =
                s1.loss_backward(&StageInput::Hidden(&m_new), &batches_b[i]).unwrap();
            stores[i] = Some(m_new);
            let gx = gx.unwrap();
            let bytes = 4 * gx.len() as u64;
            bw_tx.send(gx, bytes);
            losses.push(loss);
            i += 1;
        }
        losses
    });

    let t0 = Instant::now();
    machine_a.join().unwrap();
    let thr_losses = machine_b.join().unwrap();
    let elapsed = t0.elapsed();

    assert_eq!(thr_losses.len(), seq_losses.len());
    for (a, b) in thr_losses.iter().zip(&seq_losses) {
        assert!((a - b).abs() < 1e-6, "threaded {a} vs sequential {b}");
    }
    // pacing sanity: 3 fp32 fw messages (first visits, 16 KiB each at
    // 1 MB/s) + 3 fp32 bw messages => at least ~90 ms of modeled wire time
    assert!(elapsed >= Duration::from_millis(60), "links not paced: {elapsed:?}");
}
