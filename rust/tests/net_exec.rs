//! Multi-process integration: `aq-sgd serve-stage` over real loopback
//! TCP sockets. Each test launches one OS process per (replica, stage),
//! points them at each other with `--peers`, and checks the contract the
//! transport promises: every process's trajectory is bit-identical to
//! the virtual-clock oracle (each process verifies its own column and
//! prints SERVE-OK), link shaping changes timing but never bits, config
//! mismatches are rejected at the handshake, and a killed peer or a
//! closed socket surfaces as a descriptive error on the survivors —
//! never a hang.

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::{Duration, Instant};

use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::net::session::{establish, SessionOpts, TopologyPlan};
use aq_sgd::net::FrameRx;
use aq_sgd::pipeline::serve::config_summary;
use aq_sgd::pipeline::ExecConfig;

const BIN: &str = env!("CARGO_BIN_EXE_aq-sgd");

/// Grab `n` distinct free loopback addresses. The probe listeners are
/// dropped before the stage processes bind; on loopback in a test
/// process the reuse window is benign.
fn free_addrs(n: usize) -> Vec<String> {
    let socks: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    socks.iter().map(|l| l.local_addr().unwrap().to_string()).collect()
}

/// `[("k", "v"), ...]` -> `["--k", "v", ...]`.
fn flags(pairs: &[(&str, &str)]) -> Vec<String> {
    pairs.iter().flat_map(|(k, v)| [format!("--{k}"), v.to_string()]).collect()
}

fn spawn_stage(common: &[String], peers: &str, replica: usize, stage: usize) -> Child {
    Command::new(BIN)
        .arg("serve-stage")
        .args(["--role", &format!("stage:{stage}")])
        .args(["--replica", &replica.to_string()])
        .args(["--peers", peers])
        .args(common)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve-stage")
}

struct Done {
    replica: usize,
    stage: usize,
    code: Option<i32>,
    stdout: String,
    stderr: String,
}

impl Done {
    fn assert_serve_ok(&self) {
        assert_eq!(
            self.code,
            Some(0),
            "replica {} stage {} failed\nstdout:\n{}\nstderr:\n{}",
            self.replica,
            self.stage,
            self.stdout,
            self.stderr
        );
        let want = format!("SERVE-OK replica={} stage={}", self.replica, self.stage);
        assert!(
            self.stdout.contains(&want),
            "replica {} stage {} printed no {want:?}:\n{}",
            self.replica,
            self.stage,
            self.stdout
        );
        assert!(
            self.stdout.contains("oracle=bit-identical"),
            "replica {} stage {} skipped the oracle check:\n{}",
            self.replica,
            self.stage,
            self.stdout
        );
    }

    fn assert_failed_with(&self, keywords: &[&str]) {
        assert_ne!(
            self.code,
            Some(0),
            "replica {} stage {} exited clean after its peer went away\nstdout:\n{}",
            self.replica,
            self.stage,
            self.stdout
        );
        let err = self.stderr.to_lowercase();
        assert!(
            keywords.iter().any(|k| err.contains(k)),
            "replica {} stage {} stderr has none of {keywords:?}:\n{}",
            self.replica,
            self.stage,
            self.stderr
        );
    }
}

/// Poll every child to completion (or kill the stragglers at the
/// deadline and fail with their stderr) and collect outputs.
fn wait_all(mut procs: Vec<(usize, usize, Child)>, deadline: Duration) -> Vec<Done> {
    let t0 = Instant::now();
    let mut timed_out = false;
    while !procs.iter_mut().all(|(_, _, c)| c.try_wait().unwrap().is_some()) {
        if t0.elapsed() > deadline {
            timed_out = true;
            for (_, _, c) in procs.iter_mut() {
                c.kill().ok();
            }
            break;
        }
        thread::sleep(Duration::from_millis(25));
    }
    let done: Vec<Done> = procs
        .into_iter()
        .map(|(replica, stage, c)| {
            let out = c.wait_with_output().expect("collect child output");
            Done {
                replica,
                stage,
                code: out.status.code(),
                stdout: String::from_utf8_lossy(&out.stdout).into_owned(),
                stderr: String::from_utf8_lossy(&out.stderr).into_owned(),
            }
        })
        .collect();
    if timed_out {
        let mut dump = String::new();
        for d in &done {
            dump.push_str(&format!(
                "replica {} stage {}: code {:?}\nstderr:\n{}\n",
                d.replica, d.stage, d.code, d.stderr
            ));
        }
        panic!("grid did not finish within {deadline:?}\n{dump}");
    }
    done
}

/// Launch the full (dp x stages) grid over fresh loopback ports and wait.
fn run_grid(common: &[String], stages: usize, dp: usize, deadline: Duration) -> Vec<Done> {
    let peers = free_addrs(stages * dp).join(",");
    let procs: Vec<(usize, usize, Child)> = (0..dp)
        .flat_map(|r| (0..stages).map(move |s| (r, s)))
        .map(|(r, s)| (r, s, spawn_stage(common, &peers, r, s)))
        .collect();
    wait_all(procs, deadline)
}

#[test]
fn two_process_loopback_smoke() {
    let common = flags(&[
        ("compression", "aqsgd:fw2bw4"),
        ("schedule", "gpipe"),
        ("stages", "2"),
        ("el", "32"),
        ("n-micro", "2"),
        ("micro-batch", "2"),
        ("steps", "3"),
        ("seed", "7"),
    ]);
    for d in run_grid(&common, 2, 1, Duration::from_secs(60)) {
        d.assert_serve_ok();
    }
}

/// The acceptance grid from the issue: 2 replicas x 4 stages, AQ-SGD
/// activations + error-compensated DP gradients, every one of the 8
/// processes bit-identical to the virtual-clock oracle.
#[test]
fn acceptance_two_replicas_four_stages_bit_identical() {
    let common = flags(&[
        ("compression", "aqsgd:fw2bw4"),
        ("dp", "2"),
        ("dp-codec", "ef:directq:fw4bw4"),
        ("schedule", "gpipe"),
        ("stages", "4"),
        ("el", "32"),
        ("n-micro", "4"),
        ("micro-batch", "2"),
        ("steps", "3"),
        ("seed", "7"),
    ]);
    for d in run_grid(&common, 4, 2, Duration::from_secs(120)) {
        d.assert_serve_ok();
    }
}

/// Shaping (bandwidth cap + latency + jitter + forced 3-byte syscalls)
/// may change when frames arrive, never their bytes: the oracle check
/// still passes on every process.
#[test]
fn shaped_links_change_timing_never_bits() {
    let common = flags(&[
        ("compression", "aqsgd:fw2bw4"),
        ("schedule", "gpipe"),
        ("stages", "2"),
        ("el", "32"),
        ("n-micro", "2"),
        ("micro-batch", "2"),
        ("steps", "3"),
        ("seed", "7"),
        ("shape-rate", "200mbps"),
        ("shape-latency-ms", "2"),
        ("shape-jitter-ms", "1"),
        ("shape-chunk", "3"),
    ]);
    for d in run_grid(&common, 2, 1, Duration::from_secs(60)) {
        d.assert_serve_ok();
    }
}

/// SIGKILL one stage of a running 3-stage job: both survivors must exit
/// nonzero with a descriptive network error (closed link, tcp error, or
/// the stall deadline), never hang.
#[test]
fn chaos_killing_a_stage_fails_survivors_cleanly() {
    let peers = free_addrs(3).join(",");
    let common = flags(&[
        ("compression", "aqsgd:fw2bw4"),
        ("schedule", "gpipe"),
        ("stages", "3"),
        ("el", "32"),
        ("n-micro", "2"),
        ("micro-batch", "2"),
        ("steps", "500"),
        ("seed", "7"),
        ("shape-latency-ms", "10"),
        ("stall-timeout-ms", "4000"),
        ("skip-oracle", "true"),
    ]);
    let mut procs: Vec<(usize, usize, Child)> =
        (0..3).map(|s| (0, s, spawn_stage(&common, &peers, 0, s))).collect();
    // let the grid hand-shake and get a few steps deep, then pull the
    // middle stage out from under it
    thread::sleep(Duration::from_millis(1500));
    let (_, _, mut victim) = procs.remove(1);
    victim.kill().expect("kill stage 1");
    victim.wait().expect("reap stage 1");
    for d in wait_all(procs, Duration::from_secs(30)) {
        d.assert_failed_with(&["closed", "stall", "tcp", "reset", "broken", "connection"]);
    }
}

/// Close a socket mid-step (deterministically, from inside the test):
/// the test process impersonates stage 1 — real handshake via
/// `net::session` — receives the first forward frame, then drops every
/// socket. Stage 0 must error out with a closed-link message, not hang
/// until the stall deadline either.
#[test]
fn chaos_closing_a_socket_mid_step_errors_cleanly() {
    let addrs = free_addrs(2);
    let peers = addrs.join(",");
    let job = flags(&[
        ("compression", "aqsgd:fw2bw4"),
        ("schedule", "gpipe"),
        ("stages", "2"),
        ("el", "32"),
        ("n-micro", "2"),
        ("micro-batch", "2"),
        ("steps", "5"),
        ("seed", "7"),
    ]);
    let mut extra = job.clone();
    extra.extend(flags(&[("skip-oracle", "true"), ("stall-timeout-ms", "20000")]));
    let child = spawn_stage(&extra, &peers, 0, 0);

    // build the identical config fingerprint the child computes from the
    // same flags, so the handshake accepts us as (replica 0, stage 1)
    let cli = Cli::parse_args(job.iter().cloned());
    let tcfg = TrainConfig::from_cli(&cli).unwrap();
    let ecfg = ExecConfig::from_train(&tcfg, 2, 2, 32, 5);
    let plan = TopologyPlan::parse(&peers, 2, 1).unwrap();
    let mut socks =
        establish(&plan, 0, 1, &config_summary(&ecfg), &SessionOpts::default()).unwrap();
    let first = socks.fw_in.as_mut().expect("stage 1 has a fw inbound link").recv().unwrap();
    assert!(!first.is_empty(), "empty forward frame");
    drop(socks); // closes fw rx and bw tx mid-step

    let done = wait_all(vec![(0, 0, child)], Duration::from_secs(30));
    // well under the 20s stall deadline: closure is detected as Closed,
    // not waited out
    done[0].assert_failed_with(&["closed", "tcp", "reset", "broken", "connection"]);
}

/// Two processes launched with different --compression must refuse to
/// train together: the handshake rejects the session on both sides.
#[test]
fn config_mismatch_is_rejected_at_handshake() {
    let peers = free_addrs(2).join(",");
    let base = [
        ("schedule", "gpipe"),
        ("stages", "2"),
        ("el", "32"),
        ("n-micro", "2"),
        ("micro-batch", "2"),
        ("steps", "2"),
        ("seed", "7"),
    ];
    let mut a = flags(&base);
    a.extend(flags(&[("compression", "aqsgd:fw2bw4")]));
    let mut b = flags(&base);
    b.extend(flags(&[("compression", "fp32")]));
    let pa = spawn_stage(&a, &peers, 0, 0);
    let pb = spawn_stage(&b, &peers, 0, 1);
    let done = wait_all(vec![(0, 0, pa), (0, 1, pb)], Duration::from_secs(30));
    for d in &done {
        d.assert_failed_with(&["mismatch", "rejected", "closed", "reset"]);
    }
    assert!(
        done.iter().any(|d| d.stderr.to_lowercase().contains("mismatch")),
        "neither process reported the config mismatch:\n{}\n{}",
        done[0].stderr,
        done[1].stderr
    );
}
