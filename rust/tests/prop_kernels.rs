//! Property tests pinning the word-based / fused / parallel codec
//! kernels bit-identical to the retained byte-serial scalar reference
//! (`pack::pack_scalar` / `pack::unpack_scalar` and the split
//! `encode` -> codes -> `pack` path):
//!
//!  * `pack_into` / `unpack_into` == the scalar packer, every bit width;
//!  * the fused `encode_packed_with_scale` == quantize-then-pack, same
//!    packed bytes and the same master-RNG consumption (Stochastic
//!    draws exactly one message seed; Nearest draws nothing);
//!  * `decode_packed` / `decode_packed_add` == unpack-then-dequantize,
//!    bit for bit;
//!  * all of the above at 1..=4 worker threads — the deterministic
//!    chunk map makes the packed stream worker-count independent, which
//!    the frame-level test at the bottom re-checks through the real
//!    codecs (`q*`, `aq*`, `ef:*`, `topk*`) round after round.

use aq_sgd::codec::pack;
use aq_sgd::codec::par::{Workers, CHUNK};
use aq_sgd::codec::quantizer::{Rounding, UniformQuantizer};
use aq_sgd::codec::registry::{build_mem_pair, SchemeSpec};
use aq_sgd::testing::prop::{len_in, vec_f32, Prop};
use aq_sgd::util::Rng;

/// The chunked scalar reference for the fused encode path: quantize via
/// the split-path `encode_with_scale`, pack via the byte-serial
/// `pack_scalar`, chunk by chunk in the same order the fused kernels
/// claim. For Stochastic it mirrors the documented RNG contract: one
/// message seed drawn from the master stream, chunk `i` consuming the
/// derived `chunk_rng(msg_seed, i)` in element order.
fn reference_encode_packed(q: &UniformQuantizer, x: &[f32], scale: f32, rng: &mut Rng) -> Vec<u8> {
    let mut packed = vec![0u8; pack::packed_len(x.len(), q.bits)];
    let b_chunk = CHUNK * q.bits as usize / 8;
    match q.rounding {
        Rounding::Nearest => {
            let mut codes = vec![0u8; x.len()];
            q.encode_with_scale(x, scale, &mut codes, rng); // Nearest draws nothing
            pack::pack_scalar(&codes, q.bits, &mut packed);
        }
        Rounding::Stochastic => {
            let msg_seed = rng.next_u64();
            for (i, (xc, pc)) in x.chunks(CHUNK).zip(packed.chunks_mut(b_chunk)).enumerate() {
                let mut crng = UniformQuantizer::chunk_rng(msg_seed, i);
                let mut codes = vec![0u8; xc.len()];
                q.encode_with_scale(xc, scale, &mut codes, &mut crng);
                pack::pack_scalar(&codes, q.bits, pc);
            }
        }
    }
    packed
}

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// One full fused-vs-reference check: packed bytes, RNG consumption,
/// and both decode forms, at the given worker count.
fn check_fused(bits: u8, rounding: Rounding, x: &[f32], workers: usize, seed: u64) {
    let ctx = format!("bits={bits} {rounding:?} n={} w={workers}", x.len());
    let q = UniformQuantizer::new(bits, rounding);
    let scale = UniformQuantizer::checked_scale(x).unwrap();
    let pool = Workers::new(workers);

    let mut rng_fused = Rng::new(seed);
    let mut rng_ref = rng_fused.clone();
    let mut packed = vec![0u8; pack::packed_len(x.len(), bits)];
    q.encode_packed_with_scale(x, scale, &mut packed, &mut rng_fused, &pool);
    let expect = reference_encode_packed(&q, x, scale, &mut rng_ref);
    assert_eq!(packed, expect, "{ctx}: fused packed bytes diverged from scalar reference");
    // same master-RNG consumption: the streams stay in lockstep after
    // the encode, so codecs interleaving other draws stay reproducible
    assert_eq!(rng_fused.next_u64(), rng_ref.next_u64(), "{ctx}: RNG consumption diverged");

    // fused decode == scalar unpack + split-path dequantize
    let mut codes = vec![0u8; x.len()];
    pack::unpack_scalar(&packed, bits, &mut codes);
    let mut out_ref = vec![0f32; x.len()];
    q.decode(&codes, scale, &mut out_ref);
    let mut out = vec![0f32; x.len()];
    q.decode_packed(&packed, scale, &mut out, &pool);
    assert_eq!(bits_of(&out), bits_of(&out_ref), "{ctx}: decode_packed diverged");

    // and the accumulating form (the AQ buffer advance)
    let base: Vec<f32> = (0..x.len()).map(|i| (i as f32) * 0.25 - 1.0).collect();
    let mut acc = base.clone();
    let mut acc_ref = base;
    q.decode_packed_add(&packed, scale, &mut acc, &pool);
    q.decode_add(&codes, scale, &mut acc_ref);
    assert_eq!(bits_of(&acc), bits_of(&acc_ref), "{ctx}: decode_packed_add diverged");
}

#[test]
fn fused_kernels_match_scalar_reference_exhaustively() {
    // every bit width x odd tails around the word and chunk boundaries
    // x both roundings x 1..=4 workers
    let mut rng = Rng::new(0x5EED);
    let lens = [0usize, 1, 7, 9, 64, CHUNK - 1, CHUNK, CHUNK + 9, 2 * CHUNK + 7];
    for &n in &lens {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() * 2.0).collect();
        for bits in 1..=8u8 {
            for rounding in [Rounding::Nearest, Rounding::Stochastic] {
                for workers in 1..=4usize {
                    check_fused(bits, rounding, &x, workers, 0xFEED ^ n as u64);
                }
            }
        }
    }
}

#[test]
fn prop_fused_kernels_match_reference_on_random_shapes() {
    Prop::check("fused == scalar reference", |rng| {
        let n = len_in(rng, 1, 2 * CHUNK + 17);
        let bits = 1 + rng.below(8) as u8;
        let workers = 1 + rng.below(4);
        let rounding =
            if rng.below(2) == 0 { Rounding::Nearest } else { Rounding::Stochastic };
        let x = vec_f32(rng, n, 3.0);
        check_fused(bits, rounding, &x, workers, rng.next_u64());
    });
}

#[test]
fn prop_word_packers_match_scalar_reference() {
    Prop::check("pack_into == pack_scalar", |rng| {
        let bits = 1 + rng.below(8) as u8;
        let n = len_in(rng, 0, 3 * 64 + 9);
        // dirty high bits on purpose: the word paths must mask exactly
        // like the scalar reference does
        let codes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let mut fast = vec![0u8; pack::packed_len(n, bits)];
        let mut slow = vec![0u8; pack::packed_len(n, bits)];
        pack::pack_into(&codes, bits, &mut fast);
        pack::pack_scalar(&codes, bits, &mut slow);
        assert_eq!(fast, slow, "pack bits={bits} n={n}");
        let mut out_fast = vec![0u8; n];
        let mut out_slow = vec![0u8; n];
        pack::unpack_into(&fast, bits, &mut out_fast);
        pack::unpack_scalar(&slow, bits, &mut out_slow);
        assert_eq!(out_fast, out_slow, "unpack bits={bits} n={n}");
    });
}

#[test]
fn frames_are_identical_at_any_worker_count() {
    // the codec-level restatement of the determinism contract: the same
    // seeded pair produces byte-identical frames and bit-identical
    // reconstructions whether the kernels run sequentially or chunked
    // across a pool — including Stochastic rounding, where the one
    // message-seed draw per encode is what makes this hold. el spans
    // multiple chunks so the parallel path actually engages.
    let el = CHUNK + 37;
    for spec in ["q4", "aq2", "ef:q3", "topk0.2@4"] {
        let scheme = SchemeSpec::parse(spec).unwrap();
        for rounding in [Rounding::Nearest, Rounding::Stochastic] {
            let mut baseline: Option<Vec<Vec<u8>>> = None;
            for w in 1..=4usize {
                let (mut enc, mut dec) = build_mem_pair(&scheme, el, rounding, 77).unwrap();
                enc.set_workers(w);
                dec.set_workers(w);
                let mut a: Vec<f32> = (0..el).map(|i| ((i as f32) * 0.37).sin()).collect();
                let mut record: Vec<Vec<u8>> = Vec::new();
                for round in 0..3 {
                    let frame = enc.encode(&[0], &a).unwrap();
                    let out = dec.decode(&[0], &frame).unwrap();
                    record.push(frame.to_bytes());
                    record.push(out.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect());
                    for (i, v) in a.iter_mut().enumerate() {
                        *v += 0.01 * (((round * el + i) as f32) * 0.11).cos();
                    }
                }
                match &baseline {
                    None => baseline = Some(record),
                    Some(b) => {
                        assert_eq!(b, &record, "{spec} {rounding:?}: w={w} diverged from w=1");
                    }
                }
            }
        }
    }
}

#[test]
fn codec_encode_rejects_non_finite_activations() {
    // the silent-swallow bugfix at the codec level: a NaN/Inf activation
    // used to quantize to code 0 (max-abs skips NaN) and decode as a
    // plausible value; now every quantizing scheme refuses the message
    let el = 64;
    for rounding in [Rounding::Nearest, Rounding::Stochastic] {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut a = vec![0.25f32; el];
            a[11] = bad;
            for spec in ["q4", "topk0.2@8", "ef:q4"] {
                let scheme = SchemeSpec::parse(spec).unwrap();
                let (mut enc, _) = build_mem_pair(&scheme, el, rounding, 3).unwrap();
                let err = enc.encode(&[0], &a).unwrap_err().to_string();
                assert!(err.contains("non-finite"), "{spec} {rounding:?} {bad}: {err}");
            }
            // AQ's first visit is lossless full precision (Algorithm 1
            // line 5) and passes anything through; the quantized delta
            // path on revisit must reject
            let scheme = SchemeSpec::parse("aq4").unwrap();
            let (mut enc, mut dec) = build_mem_pair(&scheme, el, rounding, 3).unwrap();
            let finite = vec![0.25f32; el];
            let warm = enc.encode(&[0], &finite).unwrap();
            dec.decode(&[0], &warm).unwrap();
            let err = enc.encode(&[0], &a).unwrap_err().to_string();
            assert!(err.contains("non-finite"), "aq4 {rounding:?} {bad}: {err}");
        }
    }
}

#[test]
fn lossless_schemes_still_pass_non_finite_through() {
    // fp32/fp16 carry no quantizer and stay passthrough: debugging a
    // NaN blow-up with compression off must show the real values
    let el = 16;
    let mut a = vec![1.0f32; el];
    a[3] = f32::NAN;
    a[7] = f32::INFINITY;
    let (mut enc, mut dec) =
        build_mem_pair(&SchemeSpec::Raw32, el, Rounding::Nearest, 1).unwrap();
    let out = dec.decode(&[0], &enc.encode(&[0], &a).unwrap()).unwrap();
    assert_eq!(bits_of(&out), bits_of(&a), "fp32 must be bit-lossless, non-finite included");
    let (mut enc, mut dec) = build_mem_pair(&SchemeSpec::F16, el, Rounding::Nearest, 1).unwrap();
    let out = dec.decode(&[0], &enc.encode(&[0], &a).unwrap()).unwrap();
    assert!(out[3].is_nan() && out[7] == f32::INFINITY, "f16 lost the non-finite values");
}
