//! `aq-sgd serve` binary smoke: the serving front end launched the way
//! an operator launches it. In-process mode must carry a 64-session
//! fleet with zero admission-gate false rejects (the release CI smoke
//! runs exactly this), and the TCP split (server process + client
//! process over loopback) must serve a fleet end to end.

use std::net::TcpListener;
use std::process::{Child, Command, Stdio};

const BIN: &str = env!("CARGO_BIN_EXE_aq-sgd");

fn free_addr() -> String {
    let sock = TcpListener::bind("127.0.0.1:0").expect("probe bind");
    sock.local_addr().expect("probe addr").to_string()
}

struct Done {
    code: Option<i32>,
    stdout: String,
    stderr: String,
}

fn finish(child: Child) -> Done {
    let out = child.wait_with_output().expect("wait for aq-sgd serve");
    Done {
        code: out.status.code(),
        stdout: String::from_utf8_lossy(&out.stdout).into_owned(),
        stderr: String::from_utf8_lossy(&out.stderr).into_owned(),
    }
}

impl Done {
    fn assert_ok(&self, what: &str) {
        assert_eq!(
            self.code,
            Some(0),
            "{what} failed\n--- stdout ---\n{}\n--- stderr ---\n{}",
            self.stdout,
            self.stderr
        );
    }
}

fn serve(args: &[&str]) -> Child {
    Command::new(BIN)
        .arg("serve")
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn aq-sgd serve")
}

#[test]
fn in_process_fleet_of_64_has_zero_false_rejects() {
    let done = finish(serve(&[
        "--sessions",
        "64",
        "--stages",
        "2",
        "--el",
        "8",
        "--shard",
        "2",
        "--epochs",
        "2",
        "--batch-rows",
        "8",
        "--workers",
        "4",
        "--expect-no-rejects",
    ]));
    done.assert_ok("in-process serve");
    assert!(
        done.stdout.contains("no-rejects assertion passed"),
        "missing assertion marker:\n{}",
        done.stdout
    );
    assert!(
        done.stdout.contains("SERVE-OK sessions=64 served=64"),
        "missing SERVE-OK marker:\n{}",
        done.stdout
    );
}

#[test]
fn session_cap_refuses_descriptively_and_fails_the_assertion() {
    // Over-cap fleet with --expect-no-rejects must exit non-zero and say
    // why — the admission gate is observable, not a silent drop.
    let done = finish(serve(&[
        "--sessions",
        "6",
        "--max-sessions",
        "2",
        "--shard",
        "1",
        "--epochs",
        "1",
        "--workers",
        "1",
        "--expect-no-rejects",
    ]));
    assert_ne!(done.code, Some(0), "over-cap run must fail the no-rejects assertion");
    assert!(
        done.stderr.contains("admission gate fired"),
        "expected the assertion failure on stderr:\n{}",
        done.stderr
    );
}

#[test]
fn tcp_server_and_client_processes_serve_a_fleet() {
    let addr = free_addr();
    let server = serve(&[
        "--sessions",
        "16",
        "--stages",
        "2",
        "--el",
        "8",
        "--shard",
        "2",
        "--epochs",
        "2",
        "--listen",
        &addr,
        "--conns",
        "1",
        "--stall-timeout-ms",
        "20000",
        "--expect-no-rejects",
    ]);
    let client = serve(&[
        "--sessions",
        "16",
        "--stages",
        "2",
        "--el",
        "8",
        "--shard",
        "2",
        "--epochs",
        "2",
        "--connect",
        &addr,
        "--session-base",
        "0",
        "--stall-timeout-ms",
        "20000",
        "--expect-no-rejects",
    ]);
    let client = finish(client);
    let server = finish(server);
    client.assert_ok("serve client process");
    server.assert_ok("serve server process");
    assert!(
        client.stdout.contains("SERVE-OK sessions=16 served=16"),
        "client fleet incomplete:\n{}",
        client.stdout
    );
    assert!(
        server.stdout.contains("gateway_rows=64"),
        "server should have batched 16 sessions x 4 requests:\n{}",
        server.stdout
    );
}
