//! Integration tests over the PJRT runtime + AOT artifacts: the rust side
//! of the L1/L2/L3 contract. Requires `python -m compile.aot` (from python/)
//! (skips visibly otherwise, via `testing::require_artifacts`).

use aq_sgd::codec::quantizer::{Rounding, UniformQuantizer};
use aq_sgd::optim::AdamW;
use aq_sgd::runtime::{Engine, Manifest, QuantRuntime, StageInput, StageRuntime};
use aq_sgd::testing::require_artifacts;
use aq_sgd::util::Rng;

fn manifest(model: &str) -> Option<Manifest> {
    require_artifacts(model)
}

fn tokens(man: &Manifest, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let n = man.micro_batch().unwrap() * man.seq().unwrap();
    let v = man.vocab().unwrap();
    (0..n).map(|_| rng.below(v) as i32).collect()
}

#[test]
fn stage_shapes_and_finiteness() {
    let Some(man) = manifest("tiny") else { return };
    let engine = Engine::cpu().unwrap();
    let s0 = StageRuntime::load(&engine, &man, 0).unwrap();
    let s1 = StageRuntime::load(&engine, &man, 1).unwrap();
    let toks = tokens(&man, 1);
    let h = s0.forward(&StageInput::Tokens(&toks)).unwrap();
    assert_eq!(h.len(), man.boundary_len().unwrap());
    assert!(h.iter().all(|v| v.is_finite()));
    let (loss, gp, gx) = s1.loss_backward(&StageInput::Hidden(&h), &toks).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(gp.len(), man.stage_params(1).unwrap());
    let gx = gx.unwrap();
    assert_eq!(gx.len(), h.len());
    let (gp0, gx0) = s0.backward(&StageInput::Tokens(&toks), &gx).unwrap();
    assert_eq!(gp0.len(), man.stage_params(0).unwrap());
    assert!(gx0.is_none()); // token input has no gradient
    assert!(gp0.iter().any(|&v| v != 0.0));
}

#[test]
fn loss_artifact_matches_lossbwd() {
    let Some(man) = manifest("tiny") else { return };
    let engine = Engine::cpu().unwrap();
    let s0 = StageRuntime::load(&engine, &man, 0).unwrap();
    let s1 = StageRuntime::load(&engine, &man, 1).unwrap();
    let toks = tokens(&man, 2);
    let h = s0.forward(&StageInput::Tokens(&toks)).unwrap();
    let eval = s1.eval_loss(&StageInput::Hidden(&h), &toks).unwrap();
    let (lb, _, _) = s1.loss_backward(&StageInput::Hidden(&h), &toks).unwrap();
    assert!((eval - lb).abs() < 1e-5, "{eval} vs {lb}");
}

#[test]
fn gradients_pass_finite_difference_check() {
    // spot-check d loss / d params[i] for a few indices of the last stage
    let Some(man) = manifest("tiny") else { return };
    let engine = Engine::cpu().unwrap();
    let s0 = StageRuntime::load(&engine, &man, 0).unwrap();
    let mut s1 = StageRuntime::load(&engine, &man, 1).unwrap();
    let toks = tokens(&man, 3);
    let h = s0.forward(&StageInput::Tokens(&toks)).unwrap();
    let (_, gp, _) = s1.loss_backward(&StageInput::Hidden(&h), &toks).unwrap();

    let mut rng = Rng::new(7);
    let eps = 1e-3f32;
    for _ in 0..4 {
        let i = rng.below(s1.n_params);
        let orig = s1.params[i];
        s1.params[i] = orig + eps;
        let lp = s1.eval_loss(&StageInput::Hidden(&h), &toks).unwrap();
        s1.params[i] = orig - eps;
        let lm = s1.eval_loss(&StageInput::Hidden(&h), &toks).unwrap();
        s1.params[i] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        let an = gp[i];
        assert!(
            (fd - an).abs() <= 1e-2 * (1.0 + fd.abs().max(an.abs())),
            "param {i}: fd {fd} vs analytic {an}"
        );
    }
}

#[test]
fn hlo_adamw_matches_native() {
    let Some(man) = manifest("tiny") else { return };
    let engine = Engine::cpu().unwrap();
    let mut stage = StageRuntime::load(&engine, &man, 0).unwrap();
    let n = stage.n_params;
    let mut rng = Rng::new(11);
    let g: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();

    // native twin
    let mut native_params = stage.params.clone();
    let mut native_opt = AdamW::new(n);
    for step in 1..=3usize {
        native_opt.update(&mut native_params, &g, 1e-3);
        stage.adamw_step_hlo(&g, step, 1e-3).unwrap();
    }
    let mut max_diff = 0f32;
    for (a, b) in native_params.iter().zip(&stage.params) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-5, "adamw parity diff {max_diff}");
}

#[test]
fn pallas_quant_kernels_match_native_codec() {
    let Some(man) = manifest("tiny") else { return };
    let engine = Engine::cpu().unwrap();
    let q = QuantRuntime::load(&engine, &man).unwrap();
    let n = man.boundary_len().unwrap();
    let mut rng = Rng::new(13);
    let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let m: Vec<f32> = a.iter().map(|v| v + 0.05 * rng.normal()).collect();

    for bits in [2u8, 4, 8] {
        // AQ: sender m_new == receiver m_new (the Pallas replica property)
        let (codes, scale, m_send) = q.aq_encode(&a, &m, bits).unwrap();
        let m_recv = q.aq_decode(&codes, scale, &m, bits).unwrap();
        assert_eq!(m_send, m_recv, "bits={bits}");
        assert!(codes.iter().all(|&c| (c as u16) < (1 << bits)));
        // reconstruction within one delta quantization step
        let quant = UniformQuantizer::new(bits, Rounding::Nearest);
        let bound = quant.error_bound(scale) + 1e-6;
        for (x, y) in a.iter().zip(&m_send) {
            assert!((x - y).abs() <= bound, "bits={bits}");
        }
        // DirectQ matches the native quantizer's semantics exactly
        let (dc, ds) = q.dq_encode(&a, bits).unwrap();
        let da = q.dq_decode(&dc, ds, bits).unwrap();
        let native_scale = UniformQuantizer::scale(&a);
        assert!((ds - native_scale).abs() <= native_scale * 1e-6);
        let nb = quant.error_bound(native_scale) + 1e-6;
        for (x, y) in a.iter().zip(&da) {
            assert!((x - y).abs() <= nb);
        }
    }
}

#[test]
fn pallas_attention_model_matches_jnp_model() {
    // tiny and tiny_pallas share seed + architecture; only the attention
    // implementation differs (jnp vs Pallas flash kernel). Same input
    // must give (numerically) the same boundary activation.
    let (Some(man_j), Some(man_p)) = (manifest("tiny"), manifest("tiny_pallas")) else {
        return;
    };
    let engine = Engine::cpu().unwrap();
    let s_j = StageRuntime::load(&engine, &man_j, 0).unwrap();
    let s_p = StageRuntime::load(&engine, &man_p, 0).unwrap();
    let toks = tokens(&man_j, 4);
    let h_j = s_j.forward(&StageInput::Tokens(&toks)).unwrap();
    let h_p = s_p.forward(&StageInput::Tokens(&toks)).unwrap();
    let mut max_diff = 0f32;
    for (a, b) in h_j.iter().zip(&h_p) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-4, "pallas vs jnp attention diff {max_diff}");
}

#[test]
fn cls_artifacts_work() {
    let Some(man) = manifest("tiny_cls") else { return };
    assert_eq!(man.task().unwrap(), "cls");
    let engine = Engine::cpu().unwrap();
    let s0 = StageRuntime::load(&engine, &man, 0).unwrap();
    let s1 = StageRuntime::load(&engine, &man, 1).unwrap();
    let toks = tokens(&man, 5);
    let labels: Vec<i32> = (0..man.micro_batch().unwrap()).map(|i| (i % 2) as i32).collect();
    let h = s0.forward(&StageInput::Tokens(&toks)).unwrap();
    let (loss, gp, gx) = s1.loss_backward(&StageInput::Hidden(&h), &labels).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    // binary CE at init ~ ln 2
    assert!((loss - 0.693).abs() < 0.3, "loss {loss}");
    assert!(gp.iter().any(|&v| v != 0.0));
    assert!(gx.unwrap().iter().any(|&v| v != 0.0));
}

#[test]
fn manifest_accessors() {
    let Some(man) = manifest("tiny") else { return };
    assert_eq!(man.name(), "tiny");
    assert_eq!(man.n_stages().unwrap(), 2);
    assert_eq!(man.boundary().unwrap(), vec![4, 32, 32]);
    assert_eq!(man.example_len().unwrap(), 32 * 32);
    assert!(man.total_params().unwrap() > 10_000);
    let init = man.stage_init(0).unwrap();
    assert_eq!(init.len(), man.stage_params(0).unwrap());
    assert!(init.iter().all(|v| v.is_finite()));
}
