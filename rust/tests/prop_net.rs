//! Property tests over the network link model: FIFO ordering, bandwidth
//! conservation, latency additivity.

use aq_sgd::net::Link;
use aq_sgd::testing::prop::{len_in, Prop};

#[test]
fn prop_fifo_arrivals_monotone() {
    Prop::check("fifo order", |rng| {
        let mut link = Link::new(1e6 + rng.next_f64() * 1e9, rng.next_f64() * 0.01);
        let n = len_in(rng, 1, 200);
        let mut now = 0.0;
        let mut last_arrival = 0.0;
        for _ in 0..n {
            now += rng.next_f64() * 0.01;
            let arrival = link.transmit(now, rng.below(1_000_000) as u64);
            assert!(arrival >= last_arrival - 1e-12, "FIFO violated");
            assert!(arrival >= now + link.latency_s - 1e-12);
            last_arrival = arrival;
        }
    });
}

#[test]
fn prop_bandwidth_conservation() {
    // total occupancy equals bytes/bandwidth exactly when saturated
    Prop::check("bandwidth conservation", |rng| {
        let bw = 1e6 + rng.next_f64() * 1e9;
        let mut link = Link::new(bw, 0.0);
        let n = len_in(rng, 1, 100);
        let mut total_bytes = 0u64;
        let mut last = 0.0;
        for _ in 0..n {
            let bytes = 1 + rng.below(1_000_000) as u64;
            total_bytes += bytes;
            last = link.transmit(0.0, bytes); // all enqueued at t=0
        }
        let expect = total_bytes as f64 * 8.0 / bw;
        assert!((last - expect).abs() < expect * 1e-6 + 1e-9);
        assert_eq!(link.bytes_sent, total_bytes);
    });
}

#[test]
fn prop_latency_additive_not_serializing() {
    // latency delays delivery but does not occupy the link
    Prop::check("latency pipelining", |rng| {
        let lat = 0.001 + rng.next_f64() * 0.05;
        let mut with_lat = Link::new(1e8, lat);
        let mut no_lat = Link::new(1e8, 0.0);
        let n = len_in(rng, 2, 50);
        let mut d1 = 0.0;
        let mut d2 = 0.0;
        for _ in 0..n {
            let bytes = 1 + rng.below(100_000) as u64;
            d1 = with_lat.transmit(0.0, bytes);
            d2 = no_lat.transmit(0.0, bytes);
        }
        assert!((d1 - d2 - lat).abs() < 1e-9, "{d1} {d2} {lat}");
    });
}

#[test]
fn prop_reset_restores_state() {
    Prop::check("reset", |rng| {
        let mut link = Link::new(1e8, 0.001);
        for _ in 0..len_in(rng, 1, 20) {
            link.transmit(0.0, rng.below(100_000) as u64);
        }
        link.reset();
        assert_eq!(link.bytes_sent, 0);
        let a = link.transmit(0.0, 100);
        assert!((a - (100.0 * 8.0 / 1e8 + 0.001)).abs() < 1e-12);
    });
}
