//! Property tests over the network link model (FIFO ordering, bandwidth
//! conservation, latency additivity) and the TCP frame reassembler
//! (arbitrary segmentation is lossless; corrupted or hostile length
//! fields are errors, never panics or unbounded allocations).

use aq_sgd::codec::frame::FRAME_PRELUDE_BYTES;
use aq_sgd::codec::registry::{build_mem_pair, example_specs, CodecSpec};
use aq_sgd::codec::{Rounding, SchemeSpec};
use aq_sgd::net::tcp::{FrameAssembler, DEFAULT_MAX_FRAME, LEN_PREFIX_BYTES};
use aq_sgd::net::Link;
use aq_sgd::testing::prop::{len_in, vec_f32, Prop};

#[test]
fn prop_fifo_arrivals_monotone() {
    Prop::check("fifo order", |rng| {
        let mut link = Link::new(1e6 + rng.next_f64() * 1e9, rng.next_f64() * 0.01);
        let n = len_in(rng, 1, 200);
        let mut now = 0.0;
        let mut last_arrival = 0.0;
        for _ in 0..n {
            now += rng.next_f64() * 0.01;
            let arrival = link.transmit(now, rng.below(1_000_000) as u64);
            assert!(arrival >= last_arrival - 1e-12, "FIFO violated");
            assert!(arrival >= now + link.latency_s - 1e-12);
            last_arrival = arrival;
        }
    });
}

#[test]
fn prop_bandwidth_conservation() {
    // total occupancy equals bytes/bandwidth exactly when saturated
    Prop::check("bandwidth conservation", |rng| {
        let bw = 1e6 + rng.next_f64() * 1e9;
        let mut link = Link::new(bw, 0.0);
        let n = len_in(rng, 1, 100);
        let mut total_bytes = 0u64;
        let mut last = 0.0;
        for _ in 0..n {
            let bytes = 1 + rng.below(1_000_000) as u64;
            total_bytes += bytes;
            last = link.transmit(0.0, bytes); // all enqueued at t=0
        }
        let expect = total_bytes as f64 * 8.0 / bw;
        assert!((last - expect).abs() < expect * 1e-6 + 1e-9);
        assert_eq!(link.bytes_sent, total_bytes);
    });
}

#[test]
fn prop_latency_additive_not_serializing() {
    // latency delays delivery but does not occupy the link
    Prop::check("latency pipelining", |rng| {
        let lat = 0.001 + rng.next_f64() * 0.05;
        let mut with_lat = Link::new(1e8, lat);
        let mut no_lat = Link::new(1e8, 0.0);
        let n = len_in(rng, 2, 50);
        let mut d1 = 0.0;
        let mut d2 = 0.0;
        for _ in 0..n {
            let bytes = 1 + rng.below(100_000) as u64;
            d1 = with_lat.transmit(0.0, bytes);
            d2 = no_lat.transmit(0.0, bytes);
        }
        assert!((d1 - d2 - lat).abs() < 1e-9, "{d1} {d2} {lat}");
    });
}

// ---------------------------------------------------------------------------
// FrameAssembler: the TCP length-prefixed reassembly layer

/// All distinct direction schemes reachable from the example spec list —
/// every registered frame tag gets fuzzed through the assembler.
fn all_schemes() -> Vec<SchemeSpec> {
    let mut out: Vec<SchemeSpec> = Vec::new();
    for s in example_specs() {
        let spec = CodecSpec::parse(s).unwrap();
        for scheme in [spec.fw, spec.bw] {
            if !out.contains(&scheme) {
                out.push(scheme);
            }
        }
    }
    out
}

#[test]
fn prop_assembler_reassembles_any_segmentation() {
    // a random multi-frame stream fed in arbitrary segments (1-byte
    // dribbles, split preludes, coalesced frames) pops the exact frame
    // images, in order, with nothing left buffered
    let schemes = all_schemes();
    Prop::check("assembler segmentation", |rng| {
        let n_frames = len_in(rng, 1, 8);
        let mut stream: Vec<u8> = Vec::new();
        let mut want: Vec<Vec<u8>> = Vec::new();
        for _ in 0..n_frames {
            let scheme = schemes[rng.below(schemes.len())].clone();
            let el = len_in(rng, 1, 64);
            let seed = rng.next_u64();
            let (mut enc, _) = build_mem_pair(&scheme, el, Rounding::Nearest, seed).unwrap();
            let a = vec_f32(rng, el, 1.0);
            let bytes = enc.encode(&[0], &a).unwrap().to_bytes();
            stream.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            stream.extend_from_slice(&bytes);
            want.push(bytes);
        }
        let mut asm = FrameAssembler::new(DEFAULT_MAX_FRAME);
        let mut got: Vec<Vec<u8>> = Vec::new();
        let mut i = 0;
        while i < stream.len() {
            let n = 1 + rng.below(stream.len() - i).min(53);
            asm.push(&stream[i..i + n]).unwrap();
            i += n;
            assert!(asm.buffered() <= i, "assembler buffered beyond what it was fed");
            while let Some(f) = asm.pop() {
                got.push(f);
            }
        }
        assert_eq!(got, want, "reassembled frames diverged from the originals");
        assert!(!asm.has_partial(), "clean stream left partial bytes behind");
    });
}

#[test]
fn prop_assembler_corrupt_length_fields_error_never_panic() {
    // flip one length-bearing byte of a valid stream — the 4-byte prefix
    // or the prelude's header_len/payload_len — and feed it in random
    // segments: the prefix/prelude cross-check must surface an Err (never
    // a panic), no frame may pop, and buffering stays bounded by input
    let schemes = all_schemes();
    Prop::check("assembler corruption", |rng| {
        let scheme = schemes[rng.below(schemes.len())].clone();
        let el = len_in(rng, 1, 64);
        let (mut enc, _) = build_mem_pair(&scheme, el, Rounding::Nearest, 11).unwrap();
        let a = vec_f32(rng, el, 1.0);
        let bytes = enc.encode(&[0], &a).unwrap().to_bytes();
        let mut stream = (bytes.len() as u32).to_le_bytes().to_vec();
        stream.extend_from_slice(&bytes);
        // prefix bytes 0..4; header_len at 5..7, payload_len at 7..11
        // (offset 4 is the tag byte — not a length field)
        const LEN_OFFSETS: [usize; 10] = [0, 1, 2, 3, 5, 6, 7, 8, 9, 10];
        let pos = LEN_OFFSETS[rng.below(LEN_OFFSETS.len())];
        stream[pos] = stream[pos].wrapping_add(1 + rng.below(255) as u8);
        let mut asm = FrameAssembler::new(DEFAULT_MAX_FRAME);
        let mut errored = false;
        let mut i = 0;
        while i < stream.len() {
            let n = 1 + rng.below(stream.len() - i).min(7);
            let r = asm.push(&stream[i..i + n]);
            i += n;
            assert!(asm.buffered() <= i, "corrupt prefix made the assembler over-allocate");
            if r.is_err() {
                errored = true;
                break;
            }
        }
        assert!(errored, "corrupted byte {pos} produced no error");
        assert!(asm.pop().is_none(), "corrupted stream still yielded a frame");
    });
}

#[test]
fn prop_assembler_hostile_prefix_errors_before_allocating() {
    // a length prefix above the frame cap (or below the prelude floor)
    // dies on the 4 prefix bytes alone — the assembler never commits to
    // buffering the claimed length
    Prop::check("assembler size cap", |rng| {
        let cap = 64 + rng.below(4096);
        let mut asm = FrameAssembler::new(cap);
        let claim = cap as u32 + 1 + rng.below(1 << 20) as u32;
        let err = asm.push(&claim.to_le_bytes()).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
        assert!(asm.buffered() <= LEN_PREFIX_BYTES, "assembler allocated for a hostile prefix");

        let mut asm = FrameAssembler::new(cap);
        let tiny = rng.below(FRAME_PRELUDE_BYTES) as u32;
        let err = asm.push(&tiny.to_le_bytes()).unwrap_err();
        assert!(err.to_string().contains("shorter"), "{err}");
    });
}

#[test]
fn prop_reset_restores_state() {
    Prop::check("reset", |rng| {
        let mut link = Link::new(1e8, 0.001);
        for _ in 0..len_in(rng, 1, 20) {
            link.transmit(0.0, rng.below(100_000) as u64);
        }
        link.reset();
        assert_eq!(link.bytes_sent, 0);
        let a = link.transmit(0.0, 100);
        assert!((a - (100.0 * 8.0 / 1e8 + 0.001)).abs() < 1e-12);
    });
}
