//! Property tests over the framed codec layer: for every registered
//! scheme, (1) the serialized wire path decodes bit-identically to the
//! in-memory path, (2) wire accounting equals the actual serialized
//! buffer lengths, (3) sender/receiver replica state stays symmetric
//! across rounds, (4) malformed frames are errors, never panics, and
//! (5) the scratch hot path (`encode_into`/`FrameView`/`decode_into`)
//! is byte- and state-identical to the allocating path.

use aq_sgd::codec::frame::{
    Frame, FrameBuf, FrameView, FRAME_PRELUDE_BYTES, TAG_AQ, TAG_DIRECTQ, TAG_F16, TAG_LR,
    TAG_RAW32, TAG_TILE, TAG_TOPK,
};
use aq_sgd::codec::registry::{build_mem_pair, example_specs, CodecSpec};
use aq_sgd::codec::{Rounding, SchemeSpec};
use aq_sgd::testing::prop::{len_in, vec_f32, Prop};

/// All distinct direction schemes reachable from the example spec list.
fn all_schemes() -> Vec<SchemeSpec> {
    let mut out: Vec<SchemeSpec> = Vec::new();
    for s in example_specs() {
        let spec = CodecSpec::parse(s).unwrap();
        for scheme in [spec.fw, spec.bw] {
            if !out.contains(&scheme) {
                out.push(scheme);
            }
        }
    }
    out
}

#[test]
fn fuzz_coverage_includes_the_ef_gradient_codec() {
    // the mutation/truncation loops below iterate all_schemes(); pin that
    // the ef: error-feedback wrapper is in that set so the DP gradient
    // frames get the same fuzz pass as the activation frames
    assert!(
        all_schemes().iter().any(|s| matches!(s, SchemeSpec::Ef { .. })),
        "example_specs() lost its ef: entry — DP frames would go unfuzzed"
    );
    // same pin for the adaptive family (tile / had / lr): these carry
    // their own frame layouts (or wrap one), so losing their entries
    // would silently shrink the fuzz surface
    let schemes = all_schemes();
    assert!(schemes.iter().any(|s| matches!(s, SchemeSpec::Tile { .. })));
    assert!(schemes.iter().any(|s| matches!(s, SchemeSpec::Had { .. })));
    assert!(schemes.iter().any(|s| matches!(s, SchemeSpec::Lr { .. })));
}

#[test]
fn prop_wire_path_bit_identical_to_memory_path() {
    let schemes = all_schemes();
    Prop::check("frame wire == memory", |rng| {
        let scheme = schemes[rng.below(schemes.len())].clone();
        let el = len_in(rng, 1, 200);
        let n_ex = len_in(rng, 1, 4);
        let seed = rng.next_u64();
        // two decoders with identical initial state: one fed the in-memory
        // frame, one fed the serialize->deserialize round-trip
        let (mut enc, mut dec_mem) = build_mem_pair(&scheme, el, Rounding::Nearest, seed).unwrap();
        let (_, mut dec_wire) = build_mem_pair(&scheme, el, Rounding::Nearest, seed).unwrap();
        let ids: Vec<u64> = (0..n_ex as u64).collect();
        let mut a = vec_f32(rng, el * n_ex, 1.0);
        for round in 0..4 {
            let frame = enc.encode(&ids, &a).unwrap();
            // (2) measured wire bytes == serialized length == prelude+header+payload
            let bytes = frame.to_bytes();
            assert_eq!(frame.wire_bytes(), bytes.len() as u64);
            assert_eq!(
                frame.wire_bytes(),
                (FRAME_PRELUDE_BYTES + frame.header().len() + frame.payload().len()) as u64
            );
            // (1) serialization round-trip is lossless and decodes identically
            let wire_frame = Frame::from_bytes(&bytes).unwrap();
            assert_eq!(wire_frame, frame);
            let out_mem = dec_mem.decode(&ids, &frame).unwrap();
            let out_wire = dec_wire.decode(&ids, &wire_frame).unwrap();
            assert_eq!(out_mem, out_wire, "round {round}: wire path diverged from memory path");
            assert_eq!(out_mem.len(), a.len());
            // (3) replica symmetry: encoder and decoder state stay equal
            assert_eq!(enc.state_bytes(), dec_mem.state_bytes(), "round {round}");
            // drift the activation like a stabilizing model
            for v in a.iter_mut() {
                *v += 0.01 * rng.normal();
            }
        }
    });
}

#[test]
fn prop_truncated_frames_error_not_panic() {
    let schemes = all_schemes();
    Prop::check("truncated frames", |rng| {
        let scheme = schemes[rng.below(schemes.len())].clone();
        let el = len_in(rng, 1, 64);
        let (mut enc, mut dec) = build_mem_pair(&scheme, el, Rounding::Nearest, 7).unwrap();
        let a = vec_f32(rng, el, 1.0);
        let frame = enc.encode(&[0], &a).unwrap();
        dec.decode(&[0], &frame).unwrap();
        let bytes = frame.to_bytes();
        // cut the serialized image anywhere: parse or decode must error
        let cut = rng.below(bytes.len());
        match Frame::from_bytes(&bytes[..cut]) {
            Err(_) => {}
            Ok(f) => {
                assert!(dec.decode(&[0], &f).is_err(), "truncated frame decoded");
            }
        }
        // truncate only the payload, keeping the prelude consistent
        if !frame.payload().is_empty() {
            let short = Frame::new(
                frame.tag(),
                frame.header().to_vec(),
                frame.payload()[..frame.payload().len() - 1].to_vec(),
            );
            assert!(dec.decode(&[0], &short).is_err(), "short payload decoded");
        }
    });
}

#[test]
fn prop_mutated_frames_error_never_panic_or_overallocate() {
    // fuzz-style mutation loop over every registered scheme: random
    // truncations, tag flips, and header/payload length-field corruptions
    // of a valid serialized frame must come back as Err — never a panic
    // (the whole closure runs under Prop, so any panic fails the case
    // with a replayable seed) and never an allocation beyond the
    // configured batch shape (checked via the decoded output length).
    let schemes = all_schemes();
    Prop::check("mutated frames", |rng| {
        let scheme = schemes[rng.below(schemes.len())].clone();
        let el = len_in(rng, 1, 64);
        let n_ex = len_in(rng, 1, 3);
        let (mut enc, mut dec) = build_mem_pair(&scheme, el, Rounding::Nearest, 13).unwrap();
        let ids: Vec<u64> = (0..n_ex as u64).collect();
        let a = vec_f32(rng, el * n_ex, 1.0);
        // advance both halves once so stateful schemes are in steady
        // state (AQ frames become delta records with populated buffers)
        let warm = enc.encode(&ids, &a).unwrap();
        dec.decode(&ids, &warm).unwrap();
        let bytes = enc.encode(&ids, &a).unwrap().to_bytes();

        // (a) truncation at any cut point: the prelude's length claim no
        // longer matches, so parsing must error before any allocation
        let cut = rng.below(bytes.len());
        assert!(
            Frame::from_bytes(&bytes[..cut]).is_err(),
            "truncated frame ({cut}/{} bytes) parsed",
            bytes.len()
        );

        // (b) tag flipped to every other registered scheme tag: the
        // codec checks its tag before touching header or payload
        for tag in [TAG_RAW32, TAG_F16, TAG_DIRECTQ, TAG_AQ, TAG_TOPK, TAG_TILE, TAG_LR] {
            if tag == bytes[0] {
                continue;
            }
            let mut flipped = bytes.clone();
            flipped[0] = tag;
            let f = Frame::from_bytes(&flipped).expect("tag flip keeps lengths valid");
            assert!(dec.decode(&ids, &f).is_err(), "frame with flipped tag {tag} decoded");
        }

        // (c) header_len / payload_len corruption: any change to a length
        // field breaks the prelude's total-length equation
        let field_byte = 1 + rng.below(6); // bytes 1..=2 header_len, 3..=6 payload_len
        let mut corrupted = bytes.clone();
        corrupted[field_byte] = corrupted[field_byte].wrapping_add(1 + rng.below(255) as u8);
        assert!(
            Frame::from_bytes(&corrupted).is_err(),
            "frame with corrupted length field at byte {field_byte} parsed"
        );

        // (d) arbitrary single-bit flip: never a panic; if the frame
        // still parses and decodes, the output must keep the configured
        // batch shape (a malformed header cannot force a huge buffer)
        let mut mutated = bytes.clone();
        let pos = rng.below(mutated.len());
        mutated[pos] ^= 1 << rng.below(8);
        if let Ok(f) = Frame::from_bytes(&mutated) {
            if let Ok(out) = dec.decode(&ids, &f) {
                assert_eq!(out.len(), el * n_ex, "bit flip at {pos} changed the output shape");
            }
        }
    });
}

#[test]
fn prop_scratch_path_bit_identical_to_allocating_path() {
    // twin codec pairs with identical seeds: one driven through the
    // owned-Frame API, one through FrameBuf/FrameView + decode_into.
    // Serialized images, outputs, and replica state must agree bit for
    // bit, round after round — the refactor is wire-invariant by
    // construction.
    let schemes = all_schemes();
    Prop::check("scratch == allocating", |rng| {
        let scheme = schemes[rng.below(schemes.len())].clone();
        let el = len_in(rng, 1, 96);
        let n_ex = len_in(rng, 1, 3);
        let seed = rng.next_u64();
        let (mut enc_a, mut dec_a) = build_mem_pair(&scheme, el, Rounding::Nearest, seed).unwrap();
        let (mut enc_b, mut dec_b) = build_mem_pair(&scheme, el, Rounding::Nearest, seed).unwrap();
        let ids: Vec<u64> = (0..n_ex as u64).collect();
        let mut a = vec_f32(rng, el * n_ex, 1.0);
        let mut buf = FrameBuf::new();
        let mut out_b = vec![0f32; el * n_ex];
        for round in 0..4 {
            let frame = enc_a.encode(&ids, &a).unwrap();
            enc_b.encode_into(&ids, &a, &mut buf).unwrap();
            assert_eq!(
                buf.as_bytes(),
                frame.to_bytes().as_slice(),
                "round {round}: scratch image diverged from Frame serialization"
            );
            assert_eq!(buf.wire_bytes(), frame.wire_bytes());
            let out_a = dec_a.decode(&ids, &frame).unwrap();
            let view = FrameView::parse(buf.as_bytes()).unwrap();
            dec_b.decode_into(&ids, &view, &mut out_b).unwrap();
            assert_eq!(out_a, out_b, "round {round}: scratch decode diverged");
            assert_eq!(enc_a.state_bytes(), enc_b.state_bytes(), "round {round}");
            assert_eq!(dec_a.state_bytes(), dec_b.state_bytes(), "round {round}");
            for v in a.iter_mut() {
                *v += 0.01 * rng.normal();
            }
        }
    });
}

#[test]
fn prop_frameview_short_buffers_error_before_the_split() {
    // the Frame::parse length-handling pin: the prelude's claimed
    // lengths are validated against the actual slice before any split,
    // so a short buffer is an Err, never a panic — on both parse paths
    let schemes = all_schemes();
    Prop::check("frameview short buffers", |rng| {
        let scheme = schemes[rng.below(schemes.len())].clone();
        let el = len_in(rng, 1, 64);
        let (mut enc, _) = build_mem_pair(&scheme, el, Rounding::Nearest, 5).unwrap();
        let a = vec_f32(rng, el, 1.0);
        let bytes = enc.encode(&[0], &a).unwrap().to_bytes();
        // any strict prefix must error (the claimed total exceeds it)
        let cut = rng.below(bytes.len());
        assert!(FrameView::parse(&bytes[..cut]).is_err(), "prefix {cut} parsed");
        assert!(Frame::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} parsed (owned)");
        // a hostile prelude claiming max header + payload over a short
        // buffer must also error (no usize overflow on any platform)
        let mut evil = bytes[..FRAME_PRELUDE_BYTES].to_vec();
        evil[1..3].copy_from_slice(&u16::MAX.to_le_bytes());
        evil[3..7].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(FrameView::parse(&evil).is_err());
        // a valid image parses identically through both paths
        let v = FrameView::parse(&bytes).unwrap();
        let f = Frame::from_bytes(&bytes).unwrap();
        assert_eq!(v.to_frame(), f);
        assert_eq!(v.wire_bytes(), f.wire_bytes());
    });
}

#[test]
fn prop_hostile_element_count_claims_error_cleanly() {
    // a frame whose header claims n = u32::MAX codes: `packed_len`
    // saturates instead of wrapping (the old `(n * bits + 7) / 8`
    // wrapped small on 32-bit targets), so the expected payload length
    // stays huge and the payload check rejects the frame — an Err, not
    // a panic or an under-sized read
    Prop::check("hostile header n", |rng| {
        let el = len_in(rng, 1, 64);
        let bits = 1 + rng.below(8) as u8;
        let scheme = SchemeSpec::DirectQ { bits };
        let (mut enc, mut dec) = build_mem_pair(&scheme, el, Rounding::Nearest, 7).unwrap();
        let a = vec_f32(rng, el, 1.0);
        let frame = enc.encode(&[0], &a).unwrap();
        // directq header layout: bits u8 | n u32 | scale f32
        let mut header = frame.header().to_vec();
        header[1..5].copy_from_slice(&u32::MAX.to_le_bytes());
        let evil = Frame::new(frame.tag(), header, frame.payload().to_vec());
        let err = dec.decode(&[0], &evil).unwrap_err();
        assert!(err.to_string().contains("payload"), "{err}");
        // the untouched frame still decodes: the corruption above, not
        // collateral state damage, is what the Err was about
        assert_eq!(dec.decode(&[0], &frame).unwrap().len(), el);
    });
}

#[test]
fn prop_aq_delta_for_unknown_example_errors() {
    Prop::check("aq delta without buffer", |rng| {
        let el = len_in(rng, 1, 64);
        let bits = 2 + rng.below(7) as u8;
        let scheme = SchemeSpec::Aq { bits };
        let (mut enc, _) = build_mem_pair(&scheme, el, Rounding::Nearest, 1).unwrap();
        let (_, mut fresh_dec) = build_mem_pair(&scheme, el, Rounding::Nearest, 2).unwrap();
        let a = vec_f32(rng, el, 1.0);
        enc.encode(&[5], &a).unwrap(); // first visit (full)
        let delta_frame = enc.encode(&[5], &a).unwrap(); // delta
        let err = fresh_dec.decode(&[5], &delta_frame).unwrap_err();
        assert!(err.to_string().contains("no message buffer"), "{err}");
    });
}

#[test]
fn frame_overhead_is_small_and_accounted() {
    // the acceptance invariant, spelled out: reported bytes are the
    // frame's own buffers, and the fixed overhead is single-digit bytes
    // + the scheme header
    for s in example_specs() {
        let spec = CodecSpec::parse(s).unwrap();
        for scheme in [spec.fw, spec.bw] {
            let (mut enc, _) = build_mem_pair(&scheme, 256, Rounding::Nearest, 3).unwrap();
            let a: Vec<f32> = (0..256).map(|i| (i as f32 * 0.1).sin()).collect();
            let f = enc.encode(&[0], &a).unwrap();
            assert_eq!(
                f.wire_bytes() as usize,
                FRAME_PRELUDE_BYTES + f.header().len() + f.payload().len(),
                "{s}"
            );
            assert!(f.header().len() <= 16, "{s}: header {}", f.header().len());
        }
    }
}
