//! Golden wire-format parity tests: pin `UniformQuantizer` + `pack` byte
//! output against checked-in fixtures generated from the paper's
//! reference semantics (`python/compile/kernels/ref.py`, deterministic
//! rounding). No Python runs at test time; regenerate the fixtures with
//! `python python/compile/kernels/gen_golden.py` if the wire format is
//! ever intentionally changed.

use aq_sgd::codec::pack;
use aq_sgd::codec::quantizer::{Rounding, UniformQuantizer};
use aq_sgd::util::Rng;

const FIXTURES: &str = include_str!("fixtures/golden_quant.txt");

#[derive(Debug, Default)]
struct Case {
    name: String,
    bits: u8,
    n: usize,
    x: Vec<f32>,
    scale: f32,
    codes: Vec<u8>,
    packed: Vec<u8>,
    deq: Vec<f32>,
}

fn f32_from_hex(h: &str) -> f32 {
    f32::from_bits(u32::from_str_radix(h, 16).expect("bad f32 hex"))
}

fn parse_fixtures(text: &str) -> Vec<Case> {
    let mut cases = Vec::new();
    let mut cur: Option<Case> = None;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (key, rest) = line.split_once(' ').unwrap_or((line, ""));
        match key {
            "case" => cur = Some(Case { name: rest.to_string(), ..Case::default() }),
            "end" => {
                let c = cur.take().expect("end without case");
                assert_eq!(c.x.len(), c.n, "{}: x length vs n", c.name);
                assert_eq!(c.codes.len(), c.n, "{}: codes length vs n", c.name);
                assert_eq!(c.deq.len(), c.n, "{}: deq length vs n", c.name);
                cases.push(c);
            }
            _ => {
                let c = cur.as_mut().expect("field outside case");
                match key {
                    "bits" => c.bits = rest.parse().unwrap(),
                    "n" => c.n = rest.parse().unwrap(),
                    "x" => c.x = rest.split_whitespace().map(f32_from_hex).collect(),
                    "scale" => c.scale = f32_from_hex(rest),
                    "codes" => {
                        c.codes = rest.split_whitespace().map(|s| s.parse().unwrap()).collect()
                    }
                    "packed" => {
                        c.packed = (0..rest.len() / 2)
                            .map(|i| u8::from_str_radix(&rest[2 * i..2 * i + 2], 16).unwrap())
                            .collect()
                    }
                    "deq" => c.deq = rest.split_whitespace().map(f32_from_hex).collect(),
                    other => panic!("unknown fixture field {other:?}"),
                }
            }
        }
    }
    assert!(cur.is_none(), "unterminated case");
    cases
}

#[test]
fn golden_quantizer_and_pack_match_reference() {
    let cases = parse_fixtures(FIXTURES);
    assert!(cases.len() >= 5, "fixture file looks truncated");
    let mut rng = Rng::new(0); // unused by Rounding::Nearest
    for c in &cases {
        let q = UniformQuantizer::new(c.bits, Rounding::Nearest);

        // scale is exact (abs/max are exact f32 ops on both sides)
        let scale = UniformQuantizer::scale(&c.x);
        assert_eq!(scale.to_bits(), c.scale.to_bits(), "{}: scale drifted", c.name);

        // codes: the on-the-wire payload must match ref.py bit-for-bit
        let mut codes = vec![0u8; c.x.len()];
        let enc_scale = q.encode(&c.x, &mut codes, &mut rng);
        assert_eq!(enc_scale.to_bits(), c.scale.to_bits(), "{}", c.name);
        assert_eq!(codes, c.codes, "{}: codes drifted from ref.py", c.name);

        // packed bytes: the exact wire image
        let packed = pack::pack(&codes, c.bits);
        assert_eq!(packed, c.packed, "{}: packed bytes drifted", c.name);
        assert_eq!(packed.len(), pack::packed_len(c.x.len(), c.bits), "{}", c.name);

        // unpack restores the codes exactly
        assert_eq!(pack::unpack(&packed, c.bits, codes.len()), codes, "{}", c.name);

        // dequantization tracks the reference within f32 association noise
        // (ref.py computes (c/levels*2-1)*scale; the rust decoder folds the
        // constants — equal values, different rounding order)
        let mut deq = vec![0f32; codes.len()];
        q.decode(&codes, scale, &mut deq);
        let tol = scale * 1e-6;
        for (i, (a, b)) in deq.iter().zip(&c.deq).enumerate() {
            assert!(
                (a - b).abs() <= tol,
                "{}: deq[{i}] {a} vs ref {b} (tol {tol})",
                c.name
            );
        }
    }
}

#[test]
fn golden_covers_pack_remainders_and_bit_widths() {
    let cases = parse_fixtures(FIXTURES);
    // the fixture set must keep exercising non-byte-aligned tails and the
    // generic (non-2/4/8) pack path, or the golden test loses its teeth
    assert!(
        cases.iter().any(|c| (c.x.len() * c.bits as usize) % 8 != 0),
        "no ragged-tail case"
    );
    for bits in [2u8, 3, 4, 8] {
        assert!(cases.iter().any(|c| c.bits == bits), "no {bits}-bit case");
    }
    assert!(cases.iter().any(|c| c.x.iter().all(|&v| v == 0.0)), "no all-zeros case");
}
