//! Property tests over the pipeline schedule and the virtual-time
//! simulator: completeness, dependency-validity, physical lower bounds,
//! and monotonicity in bandwidth / message size / compute — plus the
//! event executor's pool-size independence (any worker count, same bits).

use aq_sgd::codec::CodecSpec;
use aq_sgd::pipeline::exec::{run_events, run_virtual, ExecConfig};
use aq_sgd::pipeline::{Op, PipelineSim, Schedule, SimConfig, StageTimes};
use aq_sgd::testing::prop::{len_in, Prop};

fn rand_schedule(rng: &mut aq_sgd::util::Rng) -> Schedule {
    if rng.below(2) == 0 {
        Schedule::GPipe
    } else {
        Schedule::OneFOneB
    }
}

#[test]
fn prop_schedule_complete_and_causal() {
    Prop::check("schedule completeness", |rng| {
        let k = len_in(rng, 1, 10);
        let m = len_in(rng, 1, 24);
        let sched = rand_schedule(rng);
        for s in 0..k {
            let ops = sched.ops(s, k, m);
            assert_eq!(ops.len(), 2 * m);
            let mut fwd = vec![false; m];
            let mut bwd = vec![false; m];
            for op in ops {
                match op {
                    Op::Fwd(i) => {
                        assert!(!fwd[i]);
                        fwd[i] = true;
                    }
                    Op::Bwd(i) => {
                        assert!(fwd[i], "bwd before fwd");
                        assert!(!bwd[i]);
                        bwd[i] = true;
                    }
                }
            }
            assert!(fwd.iter().chain(bwd.iter()).all(|&b| b));
        }
    });
}

#[test]
fn prop_cross_stage_fwd_order_causal() {
    // both schedules forward microbatches in index order on every stage,
    // which is what makes the cross-stage dependencies acyclic
    Prop::check("fwd order", |rng| {
        let k = len_in(rng, 2, 8);
        let m = len_in(rng, 1, 16);
        let sched = rand_schedule(rng);
        for s in 0..k {
            let fwd_order: Vec<usize> = sched
                .ops(s, k, m)
                .into_iter()
                .filter_map(|op| match op {
                    Op::Fwd(i) => Some(i),
                    _ => None,
                })
                .collect();
            assert_eq!(fwd_order, (0..m).collect::<Vec<_>>());
        }
    });
}

#[test]
fn grid_schedule_complete_causal_and_memory_bounded() {
    // exhaustive grid, not sampled: every (schedule, stage, n_stages ≤ 8,
    // n_micro ≤ 16) cell — each Fwd/Bwd exactly once, every Bwd(i) after
    // its Fwd(i), and 1F1B's in-flight activation count never exceeds the
    // stage depth (the memory bound the schedule exists to provide)
    for sched in [Schedule::GPipe, Schedule::OneFOneB] {
        for k in 1..=8usize {
            for m in 1..=16usize {
                for s in 0..k {
                    let ops = sched.ops(s, k, m);
                    assert_eq!(ops.len(), 2 * m, "{sched:?} k{k} m{m} s{s}");
                    let mut fwd = vec![false; m];
                    let mut bwd = vec![false; m];
                    let mut held = 0usize;
                    let mut peak = 0usize;
                    for op in ops {
                        match op {
                            Op::Fwd(i) => {
                                assert!(!fwd[i], "{sched:?} k{k} m{m} s{s}: double fwd {i}");
                                fwd[i] = true;
                                held += 1;
                                peak = peak.max(held);
                            }
                            Op::Bwd(i) => {
                                assert!(fwd[i], "{sched:?} k{k} m{m} s{s}: bwd {i} before fwd");
                                assert!(!bwd[i], "{sched:?} k{k} m{m} s{s}: double bwd {i}");
                                bwd[i] = true;
                                held -= 1;
                            }
                        }
                    }
                    assert!(
                        fwd.iter().chain(bwd.iter()).all(|&b| b),
                        "{sched:?} k{k} m{m} s{s}: incomplete"
                    );
                    // peak_in_flight is the advertised bound; for 1F1B it
                    // is at most the stage depth
                    assert!(
                        peak <= sched.peak_in_flight(s, k, m),
                        "{sched:?} k{k} m{m} s{s}: held {peak} > advertised bound {}",
                        sched.peak_in_flight(s, k, m)
                    );
                    if sched == Schedule::OneFOneB {
                        assert!(
                            peak <= k.min(m).max(1),
                            "1F1B k{k} m{m} s{s}: held {peak} activations, stage depth {k}"
                        );
                    }
                }
            }
        }
    }
}

fn rand_sim(rng: &mut aq_sgd::util::Rng) -> SimConfig {
    let k = len_in(rng, 1, 8);
    let m = len_in(rng, 1, 16);
    SimConfig {
        n_stages: k,
        n_micro: m,
        stage_times: (0..k)
            .map(|_| StageTimes {
                fwd_s: 0.001 + rng.next_f64() * 0.1,
                bwd_s: 0.001 + rng.next_f64() * 0.2,
            })
            .collect(),
        fw_bytes: (0..m).map(|_| rng.below(10_000_000) as u64).collect(),
        bw_bytes: rng.below(10_000_000) as u64,
        bandwidth_bps: 1e6 + rng.next_f64() * 10e9,
        link_bandwidths: None,
        latency_s: rng.next_f64() * 0.01,
        schedule: rand_schedule(rng),
        step_overhead_s: 0.0,
    }
}

#[test]
fn prop_sim_respects_compute_lower_bound() {
    Prop::check("sim lower bound", |rng| {
        let cfg = rand_sim(rng);
        let r = PipelineSim::run(&cfg);
        // no stage can finish faster than its own total compute
        for (s, t) in cfg.stage_times.iter().enumerate() {
            let busy = cfg.n_micro as f64 * (t.fwd_s + t.bwd_s);
            assert!(r.step_time_s >= busy - 1e-9, "stage {s}");
            assert!((r.stage_busy_s[s] - busy).abs() < 1e-9);
        }
        // nor faster than the serialized bytes on any link
        if cfg.n_stages > 1 {
            let fw_total: u64 = cfg.fw_bytes.iter().sum();
            assert!(r.step_time_s >= fw_total as f64 * 8.0 / cfg.bandwidth_bps - 1e-9);
        }
    });
}

#[test]
fn prop_sim_monotone_in_bandwidth() {
    Prop::check("sim bandwidth monotone", |rng| {
        let cfg = rand_sim(rng);
        let slow = PipelineSim::run(&cfg).step_time_s;
        let fast = PipelineSim::run(&SimConfig {
            bandwidth_bps: cfg.bandwidth_bps * 4.0,
            ..cfg.clone()
        })
        .step_time_s;
        assert!(fast <= slow + 1e-9, "fast {fast} slow {slow}");
    });
}

#[test]
fn prop_sim_monotone_in_message_size() {
    Prop::check("sim size monotone", |rng| {
        let cfg = rand_sim(rng);
        let base = PipelineSim::run(&cfg).step_time_s;
        let bigger = PipelineSim::run(&SimConfig {
            fw_bytes: cfg.fw_bytes.iter().map(|b| b * 2 + 100).collect(),
            bw_bytes: cfg.bw_bytes * 2 + 100,
            ..cfg.clone()
        })
        .step_time_s;
        assert!(bigger >= base - 1e-9);
    });
}

#[test]
fn prop_sim_deterministic() {
    Prop::check("sim deterministic", |rng| {
        let cfg = rand_sim(rng);
        let a = PipelineSim::run(&cfg).step_time_s;
        let b = PipelineSim::run(&cfg).step_time_s;
        assert_eq!(a, b);
    });
}

/// A small but fully-loaded event-executor cell: 3 stages x 2 replicas
/// (6 tasks), compressed activations, error-compensated DP ring.
fn events_cfg(schedule: Schedule) -> ExecConfig {
    let mut c = ExecConfig::small(CodecSpec::aqsgd(2, 4));
    c.schedule = schedule;
    c.seed = 23;
    c.n_stages = 3;
    c.n_micro = 4;
    c.micro_batch = 2;
    c.example_len = 32;
    c.steps = 3;
    c.dp_degree = 2;
    c.dp_spec = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
    c
}

#[test]
fn pool_size_cannot_reach_the_numerics() {
    // the event executor's core claim: the worker-pool size is a pure
    // throughput knob. Sweep pools from fully serialized (1 worker for
    // 6 tasks) to one-worker-per-task and beyond; every trace must be
    // bit-identical to the one virtual-clock oracle.
    for schedule in [Schedule::GPipe, Schedule::OneFOneB] {
        let base = events_cfg(schedule);
        let oracle = run_virtual(&base).expect("oracle run");
        let n_tasks = base.n_stages * base.dp_degree;
        for workers in [1, 2, base.n_stages, n_tasks, n_tasks + 3] {
            let mut c = base.clone();
            c.workers = workers;
            let ev = run_events(&c)
                .unwrap_or_else(|e| panic!("{schedule:?} pool={workers}: {e}"));
            assert!(
                ev.bit_identical(&oracle),
                "{schedule:?}: pool of {workers} diverged from the oracle"
            );
            assert_eq!(
                ev.fw_state_bytes, oracle.fw_state_bytes,
                "{schedule:?}: pool of {workers} left different codec state"
            );
        }
    }
}

#[test]
fn run_events_is_deterministic_across_repeated_runs() {
    // run-twice determinism on a contended pool: 2 workers x 6 tasks,
    // so the OS-level interleaving genuinely differs between runs while
    // the trajectory (losses, wire bytes, digests, codec state) may not
    let mut c = events_cfg(Schedule::OneFOneB);
    c.workers = 2;
    let a = run_events(&c).expect("first event run");
    let b = run_events(&c).expect("second event run");
    assert!(a.bit_identical(&b), "event executor not deterministic across runs");
    assert_eq!(a.fw_state_bytes, b.fw_state_bytes);
}

#[test]
fn prop_sim_byte_accounting_exact() {
    Prop::check("sim bytes", |rng| {
        let cfg = rand_sim(rng);
        let r = PipelineSim::run(&cfg);
        let fw_total: u64 = cfg.fw_bytes.iter().sum();
        for b in r.fw_link_bytes {
            assert_eq!(b, fw_total);
        }
        for b in r.bw_link_bytes {
            assert_eq!(b, cfg.bw_bytes * cfg.n_micro as u64);
        }
    });
}
