//! Property tests over the wire codecs (the rust mirror of the L1
//! kernels): roundtrips, error bounds, replica identity, and the
//! theoretical c_Q contraction.

use aq_sgd::codec::delta::{AqMessage, AqState};
use aq_sgd::codec::quantizer::{Rounding, UniformQuantizer};
use aq_sgd::codec::{f16, pack, quant_wire_bytes, theory, topk};
use aq_sgd::testing::prop::{len_in, vec_f32, Prop};
use aq_sgd::util::Rng;

#[test]
fn prop_pack_unpack_roundtrip() {
    Prop::check("pack/unpack", |rng| {
        let bits = 1 + rng.below(8) as u8;
        let n = len_in(rng, 0, 5000);
        let codes: Vec<u8> =
            (0..n).map(|_| (rng.next_u64() as u8) & ((1u16 << bits) - 1) as u8).collect();
        let packed = pack::pack(&codes, bits);
        assert_eq!(packed.len(), pack::packed_len(n, bits));
        assert_eq!(pack::unpack(&packed, bits, n), codes);
    });
}

#[test]
fn prop_quantizer_error_bound() {
    Prop::check("quantizer error bound", |rng| {
        let bits = 2 + rng.below(7) as u8;
        let n = len_in(rng, 1, 2000);
        let scale_mag = 10f32.powi(rng.below(7) as i32 - 3);
        let x = vec_f32(rng, n, scale_mag);
        let rounding =
            if rng.below(2) == 0 { Rounding::Nearest } else { Rounding::Stochastic };
        let q = UniformQuantizer::new(bits, rounding);
        let scale = UniformQuantizer::scale(&x);
        let xh = q.roundtrip(&x, rng);
        let bound = q.error_bound(scale) * (1.0 + 1e-5) + 1e-12;
        for (a, b) in x.iter().zip(&xh) {
            assert!((a - b).abs() <= bound, "bits={bits} err {} bound {bound}", (a - b).abs());
        }
    });
}

#[test]
fn prop_aq_replicas_bit_identical() {
    Prop::check("aq replicas", |rng| {
        let bits = 2 + rng.below(7) as u8;
        let n = len_in(rng, 1, 600);
        let st = AqState::new(bits, Rounding::Nearest);
        let mut a = vec_f32(rng, n, 1.0);
        let mut m_s: Option<Vec<f32>> = None;
        let mut m_r: Option<Vec<f32>> = None;
        for _ in 0..8 {
            let drift = 0.1 * rng.next_f32();
            for v in a.iter_mut() {
                *v += drift * rng.normal();
            }
            let mut ms = Vec::new();
            let msg = st.encode(&a, m_s.as_deref(), &mut ms, rng);
            let mut mr = Vec::new();
            st.decode(&msg, m_r.as_deref(), &mut mr).unwrap();
            assert_eq!(ms, mr);
            // wire accounting: full f32 on first visit, packed delta after
            let first = m_s.is_none();
            let want = if first { 4 * n as u64 } else { quant_wire_bytes(n, bits) };
            assert_eq!(msg.wire_bytes(bits), want);
            if let AqMessage::Delta { codes, .. } = &msg {
                assert!(codes.iter().all(|&c| (c as u16) < (1 << bits)));
            }
            m_s = Some(ms);
            m_r = Some(mr);
        }
    });
}

#[test]
fn prop_aq_error_bounded_by_delta_step() {
    // after every revisit, |a - m| <= one quantization step of the delta
    Prop::check("aq error bound", |rng| {
        let bits = 2 + rng.below(7) as u8;
        let n = len_in(rng, 1, 400);
        let st = AqState::new(bits, Rounding::Nearest);
        let a0 = vec_f32(rng, n, 2.0);
        let mut m = Vec::new();
        st.encode(&a0, None, &mut m, rng);
        let a1: Vec<f32> = a0.iter().map(|v| v + 0.05 * rng.normal()).collect();
        let mut m1 = Vec::new();
        let msg = st.encode(&a1, Some(&m), &mut m1, rng);
        if let AqMessage::Delta { scale, .. } = msg {
            let bound = st.quant.error_bound(scale) + 1e-6;
            for (x, y) in a1.iter().zip(&m1) {
                assert!((x - y).abs() <= bound);
            }
        } else {
            panic!("expected delta message");
        }
    });
}

#[test]
fn prop_f16_roundtrip_relative_error() {
    Prop::check("f16", |rng| {
        let n = len_in(rng, 1, 500);
        let mag = 10f32.powi(rng.below(9) as i32 - 4);
        let x = vec_f32(rng, n, mag);
        let mut bytes = Vec::new();
        f16::encode(&x, &mut bytes);
        assert_eq!(bytes.len(), 2 * n);
        let mut back = Vec::new();
        f16::decode(&bytes, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 6.2e-5, "{a} vs {b}");
        }
    });
}

#[test]
fn prop_topk_reconstruction() {
    Prop::check("topk", |rng| {
        let n = len_in(rng, 4, 800);
        let x = vec_f32(rng, n, 1.0);
        let frac = 0.05 + rng.next_f64() * 0.9;
        let msg = topk::encode(&x, frac, 8, rng);
        let k = ((n as f64 * frac).ceil() as usize).clamp(1, n);
        assert_eq!(msg.indices.len(), k);
        let mut out = Vec::new();
        topk::decode(&msg, 8, &mut out);
        assert_eq!(out.len(), n);
        // kept entries are the k largest: every dropped |x| <= min kept
        let mut kept: Vec<f32> = msg.indices.iter().map(|&i| x[i as usize].abs()).collect();
        kept.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let thresh = kept[0];
        for (i, v) in x.iter().enumerate() {
            if !msg.indices.contains(&(i as u32)) {
                assert!(v.abs() <= thresh + 1e-6);
            }
        }
    });
}

#[test]
fn prop_wire_bytes_monotone_in_bits() {
    Prop::check("wire bytes monotone", |rng| {
        let n = len_in(rng, 1, 10_000);
        let mut prev = 0u64;
        for bits in 1..=8u8 {
            let b = quant_wire_bytes(n, bits);
            assert!(b >= prev);
            prev = b;
        }
        assert!(prev <= 4 * n as u64 + 4); // 8-bit never beats fp32 + header
    });
}

#[test]
fn prop_theory_cq_decreases_with_bits() {
    Prop::check("c_Q", |rng| {
        let d = 1 + rng.below(1_000_000);
        let mut prev = f64::INFINITY;
        for bits in 1..=16u8 {
            let c = theory::c_q(d, bits);
            assert!(c < prev);
            prev = c;
        }
        // min_bits really is minimal
        let b = theory::min_bits(d);
        assert!(theory::c_q(d, b) < (0.5f64).sqrt());
        if b > 1 {
            assert!(theory::c_q(d, b - 1) >= (0.5f64).sqrt());
        }
    });
}

#[test]
fn prop_rng_shuffle_is_permutation() {
    Prop::check("shuffle", |rng| {
        let n = len_in(rng, 0, 300);
        let mut v: Vec<usize> = (0..n).collect();
        let mut r2 = Rng::new(rng.next_u64());
        r2.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>());
    });
}
