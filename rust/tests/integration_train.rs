//! End-to-end training integration: the coordinator over real PJRT
//! artifacts. Pins the paper's qualitative claims at test scale:
//! learning happens, AQ-SGD tracks FP32, compression saves the claimed
//! bytes, and every configuration axis (store backend, m-bits, HLO
//! codec, DP compression, schedules, tasks) trains.
//!
//! Artifact-gated: tests skip (via `testing::require_artifacts`, which
//! prints one consolidated notice) when `artifacts/<model>` has not been
//! built with `python -m compile.aot` (from python/).

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::TrainConfig;
use aq_sgd::coordinator::Trainer;
use aq_sgd::data::lm::markov_corpus;
use aq_sgd::data::cls::qnli_like;
use aq_sgd::exp;
use aq_sgd::pipeline::Schedule;
use aq_sgd::runtime::Manifest;
use aq_sgd::testing::{artifacts_root, require_artifacts};

fn have_artifacts(model: &str) -> bool {
    require_artifacts(model).is_some()
}

fn base_cfg(model: &str) -> TrainConfig {
    let mut c = TrainConfig::defaults(model);
    c.artifacts_dir = artifacts_root().to_string();
    c.epochs = 4;
    c.n_micro = 2;
    c.lr = 5e-3;
    c.warmup_steps = 3;
    c.n_examples = 48;
    c
}

fn run(cfg: TrainConfig) -> (f64, f64, u64) {
    let man = Manifest::load(&cfg.artifacts_dir, &cfg.model).unwrap();
    let data = exp::make_dataset(&cfg, &man).unwrap();
    let (train, _) = data.split_eval(0.1);
    let mut t = Trainer::new(cfg).unwrap();
    let first_loss = {
        // loss of the untouched model on the train set
        t.eval(&train).unwrap()
    };
    let stats = t.train(&train, None).unwrap();
    (first_loss, stats.final_train_loss, stats.comm_bytes)
}

#[test]
fn fp32_training_reduces_loss() {
    if !have_artifacts("tiny") {
        return;
    }
    let (first, last, _) = run(base_cfg("tiny"));
    assert!(last < first - 0.2, "no learning: {first} -> {last}");
}

#[test]
fn aqsgd_tracks_fp32_and_saves_bytes() {
    if !have_artifacts("tiny") {
        return;
    }
    let (_, fp32_loss, fp32_bytes) = run(base_cfg("tiny"));
    let mut cfg = base_cfg("tiny");
    cfg.compression = CodecSpec::aqsgd(4, 8);
    let (_, aq_loss, aq_bytes) = run(cfg);
    // fw4/bw8 AQ-SGD is loss-neutral at this scale (paper Fig. 3)
    assert!((aq_loss - fp32_loss).abs() < 0.15, "aq {aq_loss} vs fp32 {fp32_loss}");
    // and much cheaper on the wire (first epoch is full precision, the
    // other 3 epochs are ~8x/4x smaller)
    assert!(aq_bytes * 2 < fp32_bytes, "aq {aq_bytes} vs fp32 {fp32_bytes}");
}

#[test]
fn aqsgd_beats_directq_at_2bits() {
    if !have_artifacts("tiny") {
        return;
    }
    let mk = |c: CodecSpec| {
        let mut cfg = base_cfg("tiny");
        cfg.epochs = 6;
        cfg.compression = c;
        run(cfg).1
    };
    let aq = mk(CodecSpec::aqsgd(2, 4));
    let dq = mk(CodecSpec::directq(2, 4));
    assert!(aq < dq + 1e-9, "AQ {aq} should beat DirectQ {dq} at 2 bits");
}

#[test]
fn hlo_codec_path_trains_like_native() {
    // the Pallas-kernel boundary path vs the native rust codec: same
    // compression semantics, so the final losses stay close
    if !have_artifacts("tiny") {
        return;
    }
    let mut native = base_cfg("tiny");
    native.epochs = 3;
    native.compression = CodecSpec::aqsgd(4, 8);
    let mut hlo = native.clone();
    hlo.hlo_codec = true;
    let (_, l_native, b_native) = run(native);
    let (_, l_hlo, b_hlo) = run(hlo);
    assert!((l_native - l_hlo).abs() < 0.2, "native {l_native} vs hlo {l_hlo}");
    // wire accounting is nearly identical (per-batch vs per-example scale
    // headers differ by 4B * (B-1) per message)
    let ratio = b_native as f64 / b_hlo as f64;
    assert!((0.9..1.1).contains(&ratio), "bytes {b_native} vs {b_hlo}");
}

#[test]
fn stores_and_mbits_train() {
    if !have_artifacts("tiny") {
        return;
    }
    for (store, m_bits) in [("disk", None), ("mem", Some(8u8))] {
        let mut cfg = base_cfg("tiny");
        cfg.epochs = 3;
        cfg.compression = CodecSpec::aqsgd(4, 8);
        cfg.store = store.to_string();
        cfg.m_bits = m_bits;
        let (first, last, _) = run(cfg);
        assert!(last < first - 0.1, "{store}/{m_bits:?}: {first} -> {last}");
    }
}

#[test]
fn dp_with_quantized_gradients_trains() {
    if !have_artifacts("tiny") {
        return;
    }
    let mut cfg = base_cfg("tiny");
    cfg.epochs = 3;
    cfg.n_micro = 1;
    cfg.dp_degree = 2;
    cfg.dp_codec = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
    cfg.compression = CodecSpec::aqsgd(3, 6);
    cfg.n_examples = 64;
    let (first, last, _) = run(cfg);
    assert!(last < first - 0.1, "dp run: {first} -> {last}");
}

#[test]
fn ofob_schedule_numerics_match_gpipe() {
    // the schedule only affects *timing*; numerics must be identical
    if !have_artifacts("tiny") {
        return;
    }
    let mut a = base_cfg("tiny");
    a.epochs = 2;
    let mut b = a.clone();
    b.schedule = Schedule::OneFOneB;
    let (_, la, _) = run(a);
    let (_, lb, _) = run(b);
    assert!((la - lb).abs() < 1e-9, "{la} vs {lb}");
}

#[test]
fn cls_task_trains() {
    if !have_artifacts("tiny_cls") {
        return;
    }
    let mut cfg = base_cfg("tiny_cls");
    cfg.dataset = "qnli".to_string();
    cfg.epochs = 6;
    cfg.compression = CodecSpec::aqsgd(2, 4);
    let (first, last, _) = run(cfg);
    assert!(last < first - 0.03, "cls: {first} -> {last}");
}

#[test]
fn fp16_matches_fp32_closely() {
    if !have_artifacts("tiny") {
        return;
    }
    let mut a = base_cfg("tiny");
    a.epochs = 2;
    let mut b = a.clone();
    b.compression = CodecSpec::fp16();
    let (_, l32, bytes32) = run(a);
    let (_, l16, bytes16) = run(b);
    assert!((l32 - l16).abs() < 0.05, "{l32} vs {l16}");
    // fp16 frames halve the payload; the fixed frame headers keep the
    // measured ratio just under 2x
    let ratio = bytes32 as f64 / bytes16 as f64;
    assert!((1.9..=2.0).contains(&ratio), "bytes32 {bytes32} vs bytes16 {bytes16}");
}

#[test]
fn probe_shows_delta_shrinking_below_activation() {
    // Fig 1b: after warm-up, mean |delta| << mean |activation|
    let Some(man) = require_artifacts("tiny") else {
        return;
    };
    let mut cfg = base_cfg("tiny");
    cfg.epochs = 5;
    cfg.compression = CodecSpec::aqsgd(4, 8);
    let data = exp::make_dataset(&cfg, &man).unwrap();
    let (train, _) = data.split_eval(0.1);
    let mut t = Trainer::new(cfg).unwrap();
    t.train(&train, None).unwrap();
    let last = t.probe.rows.last().unwrap();
    assert!(last.2 < last.1 * 0.5, "delta {} vs act {}", last.2, last.1);
}

#[test]
fn trainer_rejects_task_mismatch() {
    if !have_artifacts("tiny") {
        return;
    }
    let cfg = base_cfg("tiny");
    let mut t = Trainer::new(cfg).unwrap();
    let cls_data = qnli_like(256, 32, 16, 0);
    assert!(t.train(&cls_data, None).is_err());
}

#[test]
fn trainer_rejects_undersized_dataset() {
    if !have_artifacts("tiny") {
        return;
    }
    let cfg = base_cfg("tiny"); // needs 2*4 = 8 examples per step
    let mut t = Trainer::new(cfg).unwrap();
    let small = markov_corpus(256, 32, 4, 0);
    assert!(t.train(&small, None).is_err());
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let Some(man) = require_artifacts("tiny") else {
        return;
    };
    let dir = std::env::temp_dir().join(format!("aqsgd_ckpt_{}", std::process::id()));
    let mut cfg = base_cfg("tiny");
    cfg.epochs = 2;
    let data = exp::make_dataset(&cfg, &man).unwrap();
    let (train, _) = data.split_eval(0.1);

    // train 2 epochs, checkpoint, continue 2 more
    let mut t1 = Trainer::new(cfg.clone()).unwrap();
    t1.train(&train, None).unwrap();
    t1.save_checkpoint(&dir).unwrap();
    t1.train(&train, None).unwrap();
    let want = t1.eval(&train).unwrap();

    // fresh trainer restored from the checkpoint must match exactly
    let mut t2 = Trainer::new(cfg).unwrap();
    t2.load_checkpoint(&dir).unwrap();
    assert_eq!(t2.steps_done(), 10); // 2 epochs x 5 steps
    t2.train(&train, None).unwrap();
    let got = t2.eval(&train).unwrap();
    assert!((want - got).abs() < 1e-6, "{want} vs {got}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_rejects_wrong_model() {
    if !have_artifacts("tiny") || !have_artifacts("tiny_cls") {
        return;
    }
    let dir = std::env::temp_dir().join(format!("aqsgd_ckpt_bad_{}", std::process::id()));
    let t1 = Trainer::new(base_cfg("tiny")).unwrap();
    t1.save_checkpoint(&dir).unwrap();
    let mut t2 = Trainer::new(base_cfg("tiny_cls")).unwrap();
    assert!(t2.load_checkpoint(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn generation_produces_valid_tokens() {
    let Some(man) = require_artifacts("tiny") else {
        return;
    };
    if !man.has("stage1.logits") {
        eprintln!("skipping: artifacts predate the logits export (re-run `python -m compile.aot` from python/)");
        return;
    }
    let trainer = Trainer::new(base_cfg("tiny")).unwrap();
    let prompt: Vec<i32> = "Hello".bytes().map(|b| b as i32).collect();
    let gcfg = aq_sgd::coordinator::generate::GenerateCfg {
        max_new_tokens: 8,
        temperature: 0.0,
        seed: 0,
    };
    let out = trainer.generate(&prompt, &gcfg).unwrap();
    assert_eq!(out.len(), 8);
    assert!(out.iter().all(|&t| t >= 0 && (t as usize) < man.vocab().unwrap()));
    // greedy decoding is deterministic
    let out2 = trainer.generate(&prompt, &gcfg).unwrap();
    assert_eq!(out, out2);
    // temperature sampling stays in range and varies with seed
    let mut g1 = gcfg;
    g1.temperature = 1.0;
    g1.seed = 1;
    let s1 = trainer.generate(&prompt, &g1).unwrap();
    assert!(s1.iter().all(|&t| (t as usize) < man.vocab().unwrap()));
}
