//! The zero-allocation steady-state pin (tier-1): after warm-up, one
//! full codec-path round — `encode_into` building the serialized frame
//! in a reused `FrameBuf`, `FrameView::parse` borrowing it, and
//! `decode_into` reconstructing into a caller buffer — performs ZERO
//! heap allocations, for the paper's main schemes (fp32 baseline,
//! AQ-SGD activations fw2/bw4, the EF DirectQ gradient compressor, and
//! the Hadamard-rotated tile-adaptive quantizer).
//! A second phase pins the same property through the executors' *link*
//! path (`send_from` out of the endpoint frame buffer, pooled wire
//! buffers, `recv_held` + `decode_into` on the far side).
//!
//! This is the mechanism behind the paper's "no additional end-to-end
//! runtime overhead" claim (§6): encode+pack must run well above
//! network speed, and per-message allocation/free traffic is exactly
//! the kind of overhead gradient-compression system studies (Zhang et
//! al.) found erasing end-to-end speedups.
//!
//! IMPORTANT: this file must stay a single-`#[test]` integration test.
//! The counting allocator is process-global, so a sibling test running
//! concurrently would perturb the measured deltas.

use std::time::Duration;

use aq_sgd::codec::frame::{FrameBuf, FrameView};
use aq_sgd::codec::registry::build_mem_pair;
use aq_sgd::codec::{CodecSpec, Rounding};
use aq_sgd::net::link_endpoints;
use aq_sgd::testing::alloc::{allocation_count, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

#[test]
fn steady_state_codec_path_is_allocation_free() {
    let el = 96usize;
    let n_ex = 4usize;
    let ids: Vec<u64> = (0..n_ex as u64).collect();
    for spec in ["fp32", "aqsgd:fw2bw4", "ef:directq:fw4bw4", "had:tile:64:directq:fw2bw4"] {
        let cs = CodecSpec::parse(spec).unwrap();
        for (dir, scheme) in [("fw", &cs.fw), ("bw", &cs.bw)] {
            let (mut enc, mut dec) = build_mem_pair(scheme, el, Rounding::Nearest, 42).unwrap();
            let mut a: Vec<f32> = (0..el * n_ex).map(|i| (i as f32 * 0.37).sin()).collect();
            let mut buf = FrameBuf::new();
            let mut out = vec![0f32; el * n_ex];

            // warm-up: first-visit records populate buffer stores and EF
            // residuals, scratch vectors and the frame arena reach their
            // steady-state capacities
            for _ in 0..4 {
                drift(&mut a);
                enc.encode_into(&ids, &a, &mut buf).unwrap();
                let view = FrameView::parse(buf.as_bytes()).unwrap();
                dec.decode_into(&ids, &view, &mut out).unwrap();
            }

            // steady state: encode + serialize-in-place + parse + decode,
            // several rounds, zero allocator calls
            let before = allocation_count();
            for _ in 0..8 {
                drift(&mut a);
                enc.encode_into(&ids, &a, &mut buf).unwrap();
                let view = FrameView::parse(buf.as_bytes()).unwrap();
                dec.decode_into(&ids, &view, &mut out).unwrap();
            }
            let allocs = allocation_count() - before;
            assert_eq!(
                allocs, 0,
                "{spec}/{dir}: {allocs} heap allocations in 8 steady-state rounds"
            );
        }
    }

    // Phase 2: the same pin through the *link* path the threaded and
    // event executors use — encode into the endpoint's frame buffer,
    // `send_from` borrowing it (the wire copy comes from the link's
    // buffer pool), `recv_held` lending the frame back and recycling the
    // previous one, `decode_into` a reused output buffer. One full
    // transport round, zero allocator calls after warm-up.
    for spec in ["fp32", "aqsgd:fw2bw4", "ef:directq:fw4bw4"] {
        let cs = CodecSpec::parse(spec).unwrap();
        let (enc, dec) = build_mem_pair(&cs.fw, el, Rounding::Nearest, 42).unwrap();
        // unpaced link: instant delivery, no residual sleeps in the test
        let (mut tx, mut rx) = link_endpoints(0, el, enc, dec, f64::INFINITY, Duration::ZERO);
        let mut a: Vec<f32> = (0..el * n_ex).map(|i| (i as f32 * 0.59).cos()).collect();
        let mut out = Vec::new();

        // warm-up: buffer stores, the link's buffer pool, and the decode
        // scratch all reach steady-state capacity
        for _ in 0..4 {
            drift(&mut a);
            tx.send(&ids, &a).unwrap();
            rx.recv_into(&ids, &mut out).unwrap();
        }

        let before = allocation_count();
        for _ in 0..8 {
            drift(&mut a);
            tx.send(&ids, &a).unwrap();
            rx.recv_into(&ids, &mut out).unwrap();
        }
        let allocs = allocation_count() - before;
        assert_eq!(
            allocs, 0,
            "{spec}/link: {allocs} heap allocations in 8 steady-state link rounds"
        );
    }
}

/// Small in-place drift, like a stabilizing model's activations — keeps
/// AQ emitting delta records without touching the allocator itself.
fn drift(a: &mut [f32]) {
    for v in a.iter_mut() {
        *v += 1.0e-4;
    }
}
