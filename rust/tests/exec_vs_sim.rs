//! The determinism contract between the pipeline runtimes: for the same
//! config and seed, the threaded executor (one worker thread per stage),
//! the event executor (fixed worker pool over a run queue), and the
//! single-threaded virtual-clock executor produce **bit-identical**
//! per-step loss, per-link wire-byte, DP-ring, and replica-digest
//! trajectories, across both schedules and the paper's codec spectrum —
//! including the Fig. 5 end-to-end cell where activations *and*
//! data-parallel model gradients are compressed. This is what turns
//! `pipeline::sim` into a verified oracle: every throughput table the
//! simulator produces is backed by runtimes whose numerics provably
//! match it.

use aq_sgd::codec::CodecSpec;
use aq_sgd::pipeline::exec::{run_events, run_threads, run_virtual, ExecConfig, ExecTrace};
use aq_sgd::pipeline::Schedule;

const SPECS: [&str; 4] =
    ["fp32", "aqsgd:fw2bw4", "hybrid:aq2/topk0.2@8", "had:tile:64:directq:fw2bw4"];

fn cfg(spec: &str, schedule: Schedule, seed: u64) -> ExecConfig {
    let mut c = ExecConfig::small(CodecSpec::parse(spec).unwrap());
    c.schedule = schedule;
    c.seed = seed;
    c.n_stages = 4;
    c.n_micro = 6;
    c.micro_batch = 2;
    c.example_len = 48;
    c.steps = 5;
    c
}

/// The Fig. 5 end-to-end-compressed cell: AQ-SGD activations + EF
/// DirectQ DP gradient frames, dp degree 2.
fn e2e_cfg(schedule: Schedule, seed: u64) -> ExecConfig {
    let mut c = cfg("aqsgd:fw2bw4", schedule, seed);
    c.n_stages = 3; // 6 threads: 2 replicas x 3 stages
    c.dp_degree = 2;
    c.dp_spec = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
    c
}

/// Assert two traces are bit-identical where the contract demands it.
fn assert_identical(a: &ExecTrace, b: &ExecTrace, what: &str) {
    assert_eq!(a.steps.len(), b.steps.len(), "{what}: step counts differ");
    for (i, (ra, rb)) in a.steps.iter().zip(&b.steps).enumerate() {
        assert_eq!(
            ra.loss.to_bits(),
            rb.loss.to_bits(),
            "{what}: step {i} loss {} vs {}",
            ra.loss,
            rb.loss
        );
        assert_eq!(ra.fw_wire_bytes, rb.fw_wire_bytes, "{what}: step {i} fw bytes");
        assert_eq!(ra.bw_wire_bytes, rb.bw_wire_bytes, "{what}: step {i} bw bytes");
        assert_eq!(ra.dp_wire_bytes, rb.dp_wire_bytes, "{what}: step {i} dp ring bytes");
        assert_eq!(ra.replica_digests, rb.replica_digests, "{what}: step {i} param digests");
    }
    // replica states must agree across modes too (same codec advances)
    assert_eq!(a.fw_state_bytes, b.fw_state_bytes, "{what}: codec state bytes");
}

#[test]
fn threads_match_sim_across_schedules_and_codecs() {
    for schedule in [Schedule::GPipe, Schedule::OneFOneB] {
        for spec in SPECS {
            let c = cfg(spec, schedule, 7);
            let sim = run_virtual(&c).expect("virtual run");
            let thr = run_threads(&c).expect("threaded run");
            assert_identical(&sim, &thr, &format!("{spec}/{schedule:?}"));
            // sanity: this is a real training trajectory, not zeros
            assert!(sim.steps.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
            if spec == "fp32" {
                // exact gradients: descent is strict (quantized specs may
                // wobble over 5 tiny steps — convergence is covered by
                // the trainer-level tests, not this determinism harness)
                assert!(
                    sim.steps.last().unwrap().loss < sim.steps[0].loss,
                    "{spec}/{schedule:?}: loss did not decrease: {:?}",
                    sim.losses()
                );
            }
        }
    }
}

#[test]
fn events_match_sim_across_schedules_and_codecs() {
    // the event executor against the same oracle grid as the threaded
    // one — a 4-worker pool driving 4 stage tasks off the run queue
    for schedule in [Schedule::GPipe, Schedule::OneFOneB] {
        for spec in SPECS {
            let c = cfg(spec, schedule, 7);
            let sim = run_virtual(&c).expect("virtual run");
            let ev = run_events(&c).expect("event run");
            assert_identical(&sim, &ev, &format!("events {spec}/{schedule:?}"));
        }
    }
}

#[test]
fn events_match_sim_in_the_end_to_end_compressed_cell() {
    // Fig. 5 cell on the worker pool: aqsgd:fw2bw4 activations +
    // ef:directq:fw4bw4 DP gradients, dp degree 2 — 6 stage tasks (2
    // replicas x 3 stages) on a deliberately undersized 2-worker pool,
    // so tasks park mid-step (including mid-ring-exchange) and resume
    for schedule in [Schedule::GPipe, Schedule::OneFOneB] {
        let mut c = e2e_cfg(schedule, 13);
        c.workers = 2;
        let sim = run_virtual(&c).expect("virtual e2e run");
        let ev = run_events(&c).expect("event e2e run");
        assert_identical(&sim, &ev, &format!("events e2e/{schedule:?}"));
        for rec in &ev.steps {
            assert!(rec.dp_wire_bytes.iter().all(|&b| b > 0));
        }
    }
}

#[test]
fn large_topology_runs_on_a_small_worker_pool() {
    // the scale pin: 64 stage tasks on a 4-worker pool. Thread-per-stage
    // would need 64 OS threads here; the event executor completes the
    // same bit-identical trajectory with 4, parking and resuming tasks
    // as frames arrive.
    let mut c = cfg("aqsgd:fw2bw4", Schedule::OneFOneB, 17);
    c.n_stages = 64;
    c.n_micro = 2;
    c.micro_batch = 1;
    c.example_len = 8;
    c.steps = 2;
    c.workers = 4;
    let sim = run_virtual(&c).expect("virtual 64-stage run");
    let ev = run_events(&c).expect("event 64-stage run");
    assert_identical(&sim, &ev, "events 64 stages / 4 workers");
    assert!(ev.steps.iter().all(|r| r.loss.is_finite()));
    assert_eq!(ev.steps.last().unwrap().fw_wire_bytes.len(), 63);
}

#[test]
fn end_to_end_compressed_cell_matches_across_executors() {
    // the acceptance cell: aqsgd:fw2bw4 activations + ef:directq:fw4bw4
    // DP gradients, dp degree 2, pinned bit-identically in both modes
    for schedule in [Schedule::GPipe, Schedule::OneFOneB] {
        let c = e2e_cfg(schedule, 13);
        let sim = run_virtual(&c).expect("virtual e2e run");
        let thr = run_threads(&c).expect("threaded e2e run");
        assert_identical(&sim, &thr, &format!("e2e/{schedule:?}"));
        for (i, rec) in sim.steps.iter().enumerate() {
            // the ring shipped real frames at every stage
            assert_eq!(rec.dp_wire_bytes.len(), c.n_stages);
            assert!(rec.dp_wire_bytes.iter().all(|&b| b > 0), "step {i}: {rec:?}");
        }
    }
}

#[test]
fn replica_parameters_stay_bit_identical_across_steps() {
    // error feedback + synchronized (ring-mean) updates: the replicas'
    // parameter digests agree after every step, in both executors
    let c = e2e_cfg(Schedule::GPipe, 21);
    for trace in [run_virtual(&c).unwrap(), run_threads(&c).unwrap()] {
        for (i, rec) in trace.steps.iter().enumerate() {
            assert_eq!(rec.replica_digests.len(), c.dp_degree);
            assert!(
                rec.replica_digests.windows(2).all(|w| w[0] == w[1]),
                "{:?} step {i}: replica parameters diverged: {:?}",
                trace.executor,
                rec.replica_digests
            );
        }
        // and the trajectory moves: digests change step over step
        let first = trace.steps[0].replica_digests[0];
        let last = trace.steps.last().unwrap().replica_digests[0];
        assert_ne!(first, last, "parameters never updated");
    }
}

#[test]
fn dp_compression_shrinks_ring_bytes_in_both_modes() {
    let mut fp = e2e_cfg(Schedule::GPipe, 3);
    fp.dp_spec = CodecSpec::fp32();
    let ef = e2e_cfg(Schedule::GPipe, 3);
    let b_fp: u64 = run_threads(&fp).unwrap().steps[1].dp_wire_bytes.iter().sum();
    let b_ef: u64 = run_threads(&ef).unwrap().steps[1].dp_wire_bytes.iter().sum();
    assert!(b_ef * 6 < b_fp, "ef ring {b_ef} vs fp32 ring {b_fp}");
}

#[test]
fn trajectories_depend_on_the_seed() {
    // the twin property is meaningful only if the trajectory actually
    // varies: a different seed must give a different loss path
    let a = run_virtual(&cfg("aqsgd:fw2bw4", Schedule::GPipe, 1)).unwrap();
    let b = run_virtual(&cfg("aqsgd:fw2bw4", Schedule::GPipe, 2)).unwrap();
    assert_ne!(a.losses(), b.losses());
}

#[test]
fn threads_are_deterministic_across_repeated_runs() {
    // real threads, run twice: scheduling noise must not leak into the
    // numerics (the per-stage op order pins them) — including the DP
    // ring, whose decode order is per-sender, not per-arrival
    let c = e2e_cfg(Schedule::OneFOneB, 3);
    let r1 = run_threads(&c).expect("first threaded run");
    let r2 = run_threads(&c).expect("second threaded run");
    assert_identical(&r1, &r2, "threads x2");
}

#[test]
fn aq_replica_symmetry_holds_across_threads() {
    let c = cfg("aqsgd:fw2bw4", Schedule::GPipe, 11);
    let thr = run_threads(&c).expect("threaded run");
    for s in 0..c.n_stages - 1 {
        // sender-side buffer store (stage s) == receiver replica (s+1)
        assert!(thr.fw_state_bytes[s].0 > 0, "stage {s} encoder kept no buffers");
        assert_eq!(
            thr.fw_state_bytes[s].0,
            thr.fw_state_bytes[s + 1].1,
            "boundary {s}: sender/receiver AQ buffer replicas diverged"
        );
    }
}

#[test]
fn aq_first_epoch_is_full_precision_then_deltas() {
    let c = cfg("aqsgd:fw2bw4", Schedule::GPipe, 5);
    let thr = run_threads(&c).unwrap();
    let first: u64 = thr.steps[0].fw_wire_bytes.iter().sum();
    let steady: u64 = thr.steps.last().unwrap().fw_wire_bytes.iter().sum();
    assert!(
        steady * 4 < first,
        "AQ steady-state wire {steady} not << first-epoch wire {first}"
    );
}

#[test]
fn ofob_in_flight_never_exceeds_stage_depth_in_the_real_runtime() {
    let mut c = cfg("fp32", Schedule::OneFOneB, 9);
    c.n_micro = 12;
    let thr = run_threads(&c).unwrap();
    for (s, &peak) in thr.peak_in_flight.iter().enumerate() {
        let bound = Schedule::OneFOneB.peak_in_flight(s, c.n_stages, c.n_micro);
        assert!(peak <= bound, "stage {s}: held {peak} activations, bound {bound}");
        assert!(peak <= c.n_stages, "stage {s}: exceeded stage depth");
    }
}
