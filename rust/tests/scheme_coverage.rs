//! The scheme-coverage CI gate (tier-1): every grammar production
//! reachable from `SchemeSpec::parse` must appear in the shared
//! `example_specs` list — the list `prop_frames`, `zero_alloc`, and the
//! codec bench iterate. Registering a scheme without an example spec
//! fails here, so new codecs are fuzzed, mutation-tested, and
//! alloc-checked by construction rather than by author discipline.
//!
//! The other direction is enforced too: a production string in
//! `grammar_productions()` that no longer parses (a renamed or removed
//! scheme that forgot to update the vocabulary) also fails.

use std::collections::BTreeSet;

use aq_sgd::codec::registry::{example_specs, grammar_productions, CodecSpec};
use aq_sgd::codec::SchemeSpec;

/// Productions reached (including nested inners) by the example specs.
fn covered() -> BTreeSet<&'static str> {
    let mut out = BTreeSet::new();
    for s in example_specs() {
        let spec = CodecSpec::parse(s).unwrap_or_else(|e| panic!("example spec {s:?}: {e}"));
        spec.fw.productions(&mut out);
        spec.bw.productions(&mut out);
    }
    out
}

#[test]
fn every_grammar_production_has_an_example_spec() {
    let covered = covered();
    let missing: Vec<&str> = grammar_productions()
        .iter()
        .filter(|p| !covered.contains(**p))
        .copied()
        .collect();
    assert!(
        missing.is_empty(),
        "grammar productions {missing:?} have no example_specs entry — \
         they would ship unfuzzed, un-mutation-tested, and un-alloc-checked. \
         Add a representative spec to codec::registry::example_specs()."
    );
}

#[test]
fn no_example_spec_reaches_an_unregistered_production() {
    // the inverse guard: example specs cannot cover productions the
    // grammar vocabulary does not declare (grammar_productions() and
    // SchemeSpec::production() drifting apart)
    let declared: BTreeSet<&str> = grammar_productions().iter().copied().collect();
    for p in covered() {
        assert!(
            declared.contains(p),
            "example_specs reaches production {p:?} that grammar_productions() does not declare"
        );
    }
}

#[test]
fn every_production_has_a_parsing_exemplar() {
    // one canonical exemplar per production, kept here as executable
    // documentation of the direction grammar
    let exemplars = [
        ("fp32", "fp32"),
        ("fp16", "fp16"),
        ("directq", "q4"),
        ("aq", "aq2"),
        ("topk", "topk0.2@8"),
        ("ef", "ef:q4"),
        ("tile", "tile:64:q4"),
        ("had", "had:q4"),
        ("lr", "lr:4:q4"),
    ];
    let mut seen = BTreeSet::new();
    for (prod, spec) in exemplars {
        let scheme = SchemeSpec::parse(spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        assert_eq!(scheme.production(), prod, "{spec:?} parsed to the wrong production");
        seen.insert(prod);
    }
    // the exemplar table itself covers the whole vocabulary
    for p in grammar_productions() {
        assert!(seen.contains(p), "production {p:?} has no exemplar in this table");
    }
}
