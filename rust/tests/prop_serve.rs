//! Properties of the serving front end (`aq_sgd::serve`).
//!
//! The load-bearing claim is **session isolation**: a session's numerics
//! depend only on (config, session id) — never on which strangers share
//! the server, the batches, or the wire. Two interleaved sessions pushed
//! through the same shared stages with `aqsgd:fw2bw4` must each produce
//! exactly the loss bits, cut-layer digest, and codec-state words they
//! produce running alone. That is AQ-SGD's replica-symmetry invariant
//! lifted to a multi-tenant front end: per-session codec replicas,
//! frozen server stages, row-wise stage math, per-example frame records.

use std::time::Duration;

use aq_sgd::codec::{CodecSpec, Rounding};
use aq_sgd::serve::admission::AdmissionCfg;
use aq_sgd::serve::batch::BatchCfg;
use aq_sgd::serve::{run_serve, run_serve_sessions, ServeConfig, SessionRecord};

fn base_cfg() -> ServeConfig {
    ServeConfig {
        sessions: 2,
        server_stages: 2,
        example_len: 8,
        spec: CodecSpec::parse("aqsgd:fw2bw4").expect("spec"),
        rounding: Rounding::Stochastic,
        seed: 13,
        shard: 3,
        epochs: 3, // revisits: epochs >= 2 exercises the AQ delta path
        infer_every: 0,
        batch: BatchCfg { rows: 2, max_wait: Duration::from_micros(100) },
        workers: 2,
        latency: Duration::from_micros(20),
        ..ServeConfig::default()
    }
}

/// Every observable a session records, as comparable bit patterns.
fn bits(r: &SessionRecord) -> (Vec<u32>, u64, u64, (u64, u64), (u64, u64)) {
    (
        r.losses.iter().map(|v| v.to_bits()).collect(),
        r.digest,
        r.infer_digest,
        r.client_state,
        r.server_state,
    )
}

fn assert_identical(solo: &SessionRecord, shared: &SessionRecord) {
    assert_eq!(solo.session, shared.session);
    assert!(solo.rejected.is_none() && shared.rejected.is_none());
    assert_eq!(
        bits(solo),
        bits(shared),
        "session {}: numerics changed when strangers shared the server",
        solo.session
    );
}

#[test]
fn interleaved_fine_tune_sessions_match_their_solo_runs() {
    let cfg = base_cfg();
    let solo0 = run_serve_sessions(&cfg, &[0]).expect("solo 0");
    let solo1 = run_serve_sessions(&cfg, &[1]).expect("solo 1");
    let both = run_serve_sessions(&cfg, &[0, 1]).expect("interleaved");

    assert_eq!(both.sessions.len(), 2);
    assert_identical(&solo0.sessions[0], &both.sessions[0]);
    assert_identical(&solo1.sessions[0], &both.sessions[1]);
    // sanity: the sessions did real, distinct work
    assert_eq!(both.sessions[0].losses.len(), 9);
    assert_ne!(
        bits(&both.sessions[0]).0,
        bits(&both.sessions[1]).0,
        "distinct sessions train distinct cut layers on distinct shards"
    );
}

#[test]
fn inference_and_fine_tune_mix_is_still_isolated() {
    // infer_every=2: session 0 runs split inference, session 1 fine-tunes,
    // sharing batches — each must match its solo run bit for bit.
    let cfg = ServeConfig { infer_every: 2, ..base_cfg() };
    let solo0 = run_serve_sessions(&cfg, &[0]).expect("solo 0");
    let solo1 = run_serve_sessions(&cfg, &[1]).expect("solo 1");
    let both = run_serve_sessions(&cfg, &[0, 1]).expect("mixed");

    assert!(both.sessions[0].losses.is_empty(), "session 0 is inference");
    assert_eq!(both.sessions[1].losses.len(), 9, "session 1 fine-tunes");
    assert_identical(&solo0.sessions[0], &both.sessions[0]);
    assert_identical(&solo1.sessions[0], &both.sessions[1]);
}

#[test]
fn isolation_holds_across_batch_geometry() {
    // Same fleet under different batching knobs: batch shape moves
    // latency and padding, never a single session-visible bit.
    let wide = ServeConfig {
        batch: BatchCfg { rows: 8, max_wait: Duration::from_micros(400) },
        ..base_cfg()
    };
    let narrow = ServeConfig {
        batch: BatchCfg { rows: 1, max_wait: Duration::from_micros(50) },
        ..base_cfg()
    };
    let a = run_serve_sessions(&wide, &[0, 1]).expect("wide batches");
    let b = run_serve_sessions(&narrow, &[0, 1]).expect("row-at-a-time");
    for (x, y) in a.sessions.iter().zip(&b.sessions) {
        assert_identical(x, y);
    }
    assert_eq!(b.gateway.padded_rows, 0, "1-row batches never pad");
}

#[test]
fn thousand_concurrent_sessions_with_batching_zero_false_rejects() {
    // The acceptance bar: >= 1000 concurrent sessions over one gateway
    // with cross-session batching on, nothing falsely refused. One
    // worker keeps the schedule canonical: every client's OPEN enters
    // the FIFO uplink before any reply-driven CLOSE can, so the table's
    // high-water mark must reach the full fleet.
    let cfg = ServeConfig {
        sessions: 1000,
        server_stages: 1,
        example_len: 4,
        shard: 1,
        epochs: 1,
        infer_every: 4,
        batch: BatchCfg { rows: 32, max_wait: Duration::from_micros(200) },
        admission: AdmissionCfg::default(),
        workers: 1,
        latency: Duration::from_micros(5),
        ..base_cfg()
    };
    let report = run_serve(&cfg).expect("serve 1000 sessions");
    assert_eq!(report.sessions.len(), 1000);
    assert_eq!(report.rejected_sessions(), 0, "no admission false rejects");
    assert_eq!(report.gateway.rejected_opens, 0);
    assert_eq!(report.gateway.shed_requests, 0);
    assert_eq!(report.gateway.peak_sessions, 1000, "the whole fleet was live at once");
    assert_eq!(report.replied_rows(), 1000, "every session got its reply");
    assert_eq!(report.gateway.rows, 1000);
    assert!(
        report.gateway.batches < 1000,
        "cross-session batching coalesced rows ({} batches)",
        report.gateway.batches
    );
    for s in &report.sessions {
        assert_eq!(s.client_state.0, s.server_state.0, "session {} fw replicas", s.session);
        assert_eq!(s.client_state.1, s.server_state.1, "session {} bw replicas", s.session);
    }
}
